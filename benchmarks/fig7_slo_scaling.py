"""Paper Figure 7: SLO-scale sweep — TTFT/TPOT SLOs scaled uniformly from
2.0x (relaxed) to 0.5x (strict) at QPS/GPU in {1.25, 1.375, 1.5}.

Validates: the non-uniform power configuration matches the 6000W
4P4D-750W setup until the SLOs become highly restrictive.
"""
from __future__ import annotations

from benchmarks.common import NODE_BUDGET_W, Timer, save_artifact, sim_run
from repro.core.controller import policy_4p4d, policy_nonuniform
from repro.core.simulator import Workload

SCALES = (2.0, 1.5, 1.0, 0.75, 0.5)


def main(fast: bool = False):
    tm = Timer().start()
    n = 400 if fast else 800
    rates = (1.25,) if fast else (1.25, 1.375, 1.5)
    rows = []
    for qpg in rates:
        print(f"\nQPS/GPU = {qpg}:  scale | 4P4D-750W | 4P4D-600W | 4P-750/4D-450")
        for sc in SCALES:
            vals = []
            for pol, budget in [(policy_4p4d(750), 6000.0),
                                (policy_4p4d(600), NODE_BUDGET_W),
                                (policy_nonuniform(750, 450), NODE_BUDGET_W)]:
                wl = Workload.longbench_like(
                    n, qps=qpg * 8, seed=11,
                    ttft_slo=1.0 * sc, tpot_slo=0.040 * sc)
                _, s = sim_run(pol, wl, budget=budget)
                vals.append(s.slo_attainment)
            rows.append({"qps_per_gpu": qpg, "slo_scale": sc,
                         "4P4D-750W": vals[0], "4P4D-600W": vals[1],
                         "nonuniform": vals[2]})
            print(f"  {sc:4.2f}x | {vals[0]*100:8.1f}% | {vals[1]*100:8.1f}% "
                  f"| {vals[2]*100:8.1f}%")
    save_artifact("fig7_slo_scaling", rows, timer=tm.stop())
    return rows


if __name__ == "__main__":
    main()
