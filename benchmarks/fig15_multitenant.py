"""Multi-tenant day: SLO classes + preemption + locality (beyond the paper).

One three-tenant day — an interactive agent product (multi-turn sessions
re-sending a shared system prompt, tight TTFT, priority 2), a batch
summarization pipeline (long prefills, relaxed latency, priority 1), and
background evals (lowest priority, loosest SLO) — replayed bit-identically
against three cluster configurations under the same facility power budget:

  full       affinity routing (requests follow their cached prefixes via
             the router's own hint table) + priority preemption (an
             arriving interactive request may evict a saturated decode
             batch of strictly lower priority back to the queue);
  capacity   the PR-6-era router: pure capacity scoring, blind to prefix
             locality — sessions scatter across nodes and re-prefill
             their whole conversation every turn (preemption stays on);
  no_preempt affinity routing, but arriving high-priority work waits in
             line behind saturated low-priority decode batches.

All three arms run the identical workload, tenancy registry, prefix-cache
budget, and constant electricity tariff — the arms differ only in the
routing policy and the registry's ``preempt`` switch.

Asserted here (fast mode too — this is the CI ``bench-smoke`` gate):

* the interactive tenant's SLO attainment under ``full`` is >= both
  ablation arms' under the identical day;
* the interactive tenant's $/good-token under ``full`` is no worse than
  either ablation (locality reuse and preemption do not buy the priority
  tenant's latency with its own dollars — the per-tenant attribution in
  ``goodput.summarize`` is what makes this auditable);
* two runs of the ``full`` arm with the same seed produce bit-identical
  per-request records — the subsystem keeps the determinism contract.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Timer, dyn_ctrl, save_artifact
from repro.configs import get_config
from repro.core.autoscale import SignalTrace
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import policy_4p4d
from repro.core.costmodel import MI300X
from repro.core.prefixcache import PrefixCacheConfig
from repro.core.simulator import Workload
from repro.core.tenancy import TenantRegistry, TenantSpec

N_NODES = 3
NODE_BUDGET_W = 4000.0          # power-constrained nodes (fig9 regime)
POLICY = policy_4p4d(500)
DECODE_SLOTS = 4                # per-GPU decode cap: saturation pressure
PRICE_USD_KWH = 0.20            # constant tariff: $ differences are joules
CARBON_G_KWH = 400.0

TENANTS = (TenantSpec("interactive", ttft_slo=0.8, tpot_slo=0.040,
                      priority=2, weight=2.0),
           TenantSpec("batch", ttft_slo=4.0, tpot_slo=0.080,
                      priority=1, weight=1.0),
           TenantSpec("bgeval", ttft_slo=8.0, tpot_slo=0.200,
                      priority=0, weight=0.5))


def scale(fast: bool) -> int:
    """Session/request counts scale with this (fast mode: CI smoke)."""
    return 1 if fast else 3


def day(fast: bool, seed: int) -> Workload:
    """The three tenants' interleaved day (drawn at build time — the run
    itself is deterministic), identical across arms."""
    k = scale(fast)
    interactive = Workload.sessions(
        10 * k, turns=4, qps=2.5, tenant="interactive", seed=seed,
        system_tokens=2048, turn_tokens=256, out_tokens=96,
        ttft_slo=0.8, tpot_slo=0.040)
    batch = Workload.uniform(
        30 * k, qps=6.0, in_tokens=4096, out_tokens=512, seed=seed + 1,
        ttft_slo=4.0, tpot_slo=0.080, tenant="batch")
    bgeval = Workload.uniform(
        20 * k, qps=3.0, in_tokens=2048, out_tokens=512, seed=seed + 2,
        ttft_slo=8.0, tpot_slo=0.200, tenant="bgeval")
    return Workload(interactive.entries + batch.entries + bgeval.entries,
                    name="multitenant_day")


def _run(arm: str, fast: bool, seed: int = 5):
    assert arm in ("full", "capacity", "no_preempt"), arm
    reg = TenantRegistry(TENANTS, preempt=(arm != "no_preempt"))
    cs = ClusterSimulator(
        get_config("llama31_8b"), POLICY, N_NODES,
        node_budget_w=NODE_BUDGET_W,
        ctrl_cfg=dyn_ctrl(gpu=False, ttft_slo=2.0),
        cluster_cfg=ClusterConfig(allow_shift=True), seed=7,
        gpu=dataclasses.replace(MI300X, max_active_decode=DECODE_SLOTS),
        router_policy="capacity" if arm == "capacity" else "affinity",
        tenancy=reg, cache_cfg=PrefixCacheConfig())
    cs.price_trace = SignalTrace([0.0], [PRICE_USD_KWH],
                                 name="price", units="$/kWh")
    cs.carbon_trace = SignalTrace([0.0], [CARBON_G_KWH],
                                  name="carbon", units="gCO2/kWh")
    s = cs.run(day(fast, seed))
    for t, budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6, (t, budgets, total)
    assert all(np.isfinite(r.energy_j) and r.energy_j > 0
               for r in cs.records), "every record must carry spent joules"
    return cs, s


def fingerprint(cs) -> list:
    """Per-request record tuple list — the bit-identity gate."""
    return [(r.rid, r.tenant, r.arrival, r.prefill_done, r.finish,
             r.energy_j, r.shed_t) for r in cs.records]


def sweep(fast: bool, seed: int = 5):
    rows = []
    att = {}
    cost = {}
    for arm in ("full", "capacity", "no_preempt"):
        cs, s = _run(arm, fast, seed)
        ten = s.per_tenant
        att[arm] = ten["interactive"]["slo_attainment"]
        cost[arm] = ten["interactive"]["cost_per_good_token_usd"]
        rows.append({
            "arm": arm,
            "slo_attainment": s.slo_attainment,
            "goodput_rps": s.goodput_rps,
            "cost_per_good_token_usd": s.cost_per_good_token_usd,
            "energy_per_good_token_j": s.energy_per_good_token_j,
            "preemptions": sum(len(nd.preempt_trace) for nd in cs.nodes),
            "prefix_hit_tokens": sum(nd.prefix_hit_tokens
                                     for nd in cs.nodes),
            "per_tenant": ten,
        })
        hits = sum(nd.prefix_hit_tokens for nd in cs.nodes)
        pre = sum(len(nd.preempt_trace) for nd in cs.nodes)
        print(f"{arm:10s} interactive att={att[arm]*100:5.1f}%  "
              f"fleet att={s.slo_attainment*100:5.1f}%  "
              f"interactive $/Mtok {cost[arm]*1e6:6.3f}  "
              f"hits={hits} preempts={pre}")
    print(f"\nfull vs ablations on the interactive tenant: "
          f"{att['full']*100:.1f}% vs capacity {att['capacity']*100:.1f}% / "
          f"no_preempt {att['no_preempt']*100:.1f}%")
    assert att["full"] >= att["capacity"], \
        "affinity routing must not lose the high-priority tenant's SLO " \
        "to capacity-only routing under the identical day"
    assert att["full"] >= att["no_preempt"], \
        "priority preemption must not lose the high-priority tenant's " \
        "SLO to waiting in line under the identical day"
    assert cost["full"] <= cost["capacity"] + 1e-12, \
        "affinity must not buy the priority tenant's latency with its " \
        "own dollars vs capacity-only routing"
    assert cost["full"] <= cost["no_preempt"] + 1e-12, \
        "preemption must not buy the priority tenant's latency with its " \
        "own dollars vs waiting in line"
    # determinism gate: same arm, same seed, bit-identical records
    cs_a, _ = _run("full", fast, seed)
    cs_b, _ = _run("full", fast, seed)
    assert fingerprint(cs_a) == fingerprint(cs_b), \
        "multi-tenant runs must be bit-identical per seed"
    print("rerun determinism: bit-identical per-request records  OK")
    return rows


def main(fast: bool = False, seed: int = 5):
    tm = Timer().start()
    rows = sweep(fast, seed)
    save_artifact("fig15_multitenant", {"sweep": rows, "seed": seed},
                  timer=tm.stop())
    return rows


if __name__ == "__main__":
    main()
