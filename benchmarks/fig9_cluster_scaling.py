"""Cluster scaling (beyond the paper's single node): 1/2/4/8 nodes under a
facility power budget, LongBench + two-phase Sonnet workloads — plus a
``--fleet`` mode (32 nodes, 22k requests, mixed longbench/sonnet arrival
phases) that the macro-stepped simulator core makes tractable. Three power
regimes per scaling point:

  static        fixed per-node budgets, fixed per-GPU caps
  DynPower      fixed per-node budgets, RAPID power shifting inside each node
  DynPower+cluster  RAPID inside nodes + the coordinator moving node budgets
                    (two-level hierarchy, source-before-sink at both levels)

plus the skew experiment the cluster layer exists for: two nodes, one fed
the Sonnet prefill-heavy phase (8k in / 128 out), the other decode-heavy
(500 in / 500 out, 20 ms TPOT), static node budgets vs. cluster shifting.
Facility budget invariant is asserted on every coordinator tick inside the
simulator; this driver re-checks the recorded budget trace and requires the
cluster-shift arm to strictly beat static per-node budgets.

Nodes are deliberately budget-constrained (4000 W < 8 x 750 W peak): that is
the regime where moving watts between nodes matters.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

from benchmarks.common import Timer, dyn_ctrl, save_artifact
from repro.configs import get_config
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import policy_4p4d
from repro.core.simulator import Workload

NODE_BUDGET_W = 4000.0          # power-constrained node (paper Section 5 regime)
POLICY = policy_4p4d(500)       # 8 x 500 W fits the 4000 W node budget
QPS_PER_NODE = {"longbench": 7.0, "sonnet": 6.0}


def _workload(name: str, n_nodes: int, n_per_node: int, seed: int) -> Workload:
    qps = QPS_PER_NODE[name] * n_nodes
    if name == "longbench":
        return Workload.longbench_like(n_per_node * n_nodes, qps=qps,
                                       seed=seed)
    return Workload.sonnet_phases(qps, seed=seed, n1=n_per_node * n_nodes // 2,
                                  n2=n_per_node * n_nodes // 2)


def _run(n_nodes: int, wl=None, pinned=None, *, ctrl=None, shift=False,
         seed=0):
    cs = ClusterSimulator(get_config("llama31_8b"), POLICY, n_nodes,
                          node_budget_w=NODE_BUDGET_W, ctrl_cfg=ctrl,
                          cluster_cfg=ClusterConfig(allow_shift=shift),
                          seed=seed)
    s = cs.run(wl, pinned=pinned)
    # re-check the facility budget invariant over the recorded trace
    for t, budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6, (t, budgets, total)
    return cs, s


def regimes():
    return [
        ("static", None, False),
        ("DynPower", dyn_ctrl(gpu=False), False),
        ("DynPower+cluster", dyn_ctrl(gpu=False), True),
    ]


def scaling_sweep(fast: bool):
    node_counts = (1, 2) if fast else (1, 2, 4, 8)
    n_per_node = 80 if fast else 250
    rows = []
    for wl_name in ("longbench", "sonnet"):
        for n_nodes in node_counts:
            for reg_name, ctrl, shift in regimes():
                wl = _workload(wl_name, n_nodes, n_per_node, seed=3)
                cs, s = _run(n_nodes, wl, ctrl=ctrl, shift=shift, seed=3)
                rows.append({
                    "workload": wl_name, "nodes": n_nodes, "regime": reg_name,
                    "slo_attainment": s.slo_attainment,
                    "goodput_rps": s.goodput_rps,
                    "p90_ttft_s": s.p90_ttft, "p90_tpot_s": s.p90_tpot,
                    "qps_per_kw": s.qps_per_kw,
                    "budget_shifts": len(cs.shift_trace),
                })
                print(f"{wl_name:9s} n={n_nodes}  {reg_name:17s} "
                      f"att={s.slo_attainment*100:5.1f}%  "
                      f"goodput={s.goodput_rps:6.2f} req/s  "
                      f"shifts={len(cs.shift_trace)}")
    return rows


def skew_experiment(fast: bool):
    """Two nodes, opposite phase mixes: watts must cross the node boundary.

    Node 0 gets the Sonnet prefill-heavy phase (8k in / 128 out, 2 s TTFT —
    at 4.0 QPS it sits between the node's prefill capacity at a 4000 W
    budget, ~4.3 req/s @600 W caps, and at a boosted one, ~4.8 req/s
    @750 W); node 1 is decode-heavy (500/500, 20 ms TPOT) and — decode
    saturating by ~600 W — barely slows down when the coordinator takes its
    spare watts. Only cluster-level shifting can exploit that asymmetry."""
    n = 100 if fast else 250
    rows = {}
    for reg_name, ctrl, shift in regimes():
        if ctrl is not None:
            ctrl = dataclasses.replace(ctrl, ttft_slo=2.0)
        pinned = {
            0: Workload.uniform(n, qps=4.0, in_tokens=8192, out_tokens=128,
                                seed=11, ttft_slo=2.0,
                                tpot_slo=0.040),   # sonnet prefill-heavy
            1: Workload.uniform(n, qps=4.0, in_tokens=500, out_tokens=500,
                                seed=12, tpot_slo=0.020),   # decode-heavy
        }
        cs, s = _run(2, pinned=pinned, ctrl=ctrl, shift=shift, seed=7)
        rows[reg_name] = {
            "slo_attainment": s.slo_attainment, "goodput_rps": s.goodput_rps,
            "p90_ttft_s": s.p90_ttft, "p90_tpot_s": s.p90_tpot,
            "budget_shifts": len(cs.shift_trace),
            "final_budgets": [nd.pm.budget for nd in cs.nodes],
        }
        print(f"skew 2-node  {reg_name:17s} att={s.slo_attainment*100:5.1f}%  "
              f"{s.row()}  "
              f"budgets={[round(nd.pm.budget) for nd in cs.nodes]}")
    gain = rows["DynPower+cluster"]["slo_attainment"] - \
        rows["DynPower"]["slo_attainment"]
    print(f"\ncluster shifting vs static node budgets: "
          f"{rows['DynPower+cluster']['slo_attainment']*100:.1f}% vs "
          f"{rows['DynPower']['slo_attainment']*100:.1f}%  (+{gain*100:.1f}pp)")
    assert rows["DynPower+cluster"]["slo_attainment"] > \
        rows["DynPower"]["slo_attainment"], \
        "cluster budget shifting must strictly beat static per-node budgets"
    assert rows["DynPower+cluster"]["budget_shifts"] > 0
    return rows


def fleet_experiment(fast: bool):
    """Fleet scale: 32 nodes under one facility budget serving mixed
    longbench/sonnet arrival phases (22k requests). Each regime simulates
    ~0.7M decode iterations across 256 GPUs — intractable with one heap
    event per iteration (the pre-macro-step core managed ~8 nodes x 250
    requests in the same wall budget); with macro-stepping the whole
    scenario runs in tens of seconds."""
    n_nodes = 32
    n_per_node = 200 if fast else 500
    qps = QPS_PER_NODE["longbench"] * n_nodes
    lb = Workload.longbench_like(n_per_node * n_nodes, qps=qps, seed=17)
    sonnet = Workload.sonnet_phases(
        QPS_PER_NODE["sonnet"] * n_nodes, seed=18,
        n1=n_per_node * n_nodes // 5, n2=n_per_node * n_nodes // 5)
    wl = Workload.phased_mix([lb, sonnet], name="fleet-mix")
    rows = {}
    for reg_name, ctrl, shift in (("static", None, False),
                                  ("DynPower+cluster",
                                   dyn_ctrl(gpu=False), True)):
        t0 = time.perf_counter()
        cs, s = _run(n_nodes, wl, ctrl=ctrl, shift=shift, seed=17)
        wall = time.perf_counter() - t0
        iters = sum(nd.decode_iters for nd in cs.nodes)
        rows[reg_name] = {
            "nodes": n_nodes, "requests": len(wl.entries),
            "slo_attainment": s.slo_attainment,
            "goodput_rps": s.goodput_rps,
            "p90_ttft_s": s.p90_ttft, "p90_tpot_s": s.p90_tpot,
            "qps_per_kw": s.qps_per_kw,
            "budget_shifts": len(cs.shift_trace),
            "decode_iters": iters, "wall_s": round(wall, 2),
            "sim_s": round(cs.loop.now, 1),
        }
        print(f"fleet n={n_nodes} reqs={len(wl.entries)}  {reg_name:17s} "
              f"att={s.slo_attainment*100:5.1f}%  "
              f"goodput={s.goodput_rps:6.2f} req/s  "
              f"iters={iters}  wall={wall:.1f}s")
    if not fast:
        assert rows["static"]["requests"] >= 20_000
    return rows


def main(fast: bool = False, fleet: bool = False):
    with Timer() as tm:
        rows = scaling_sweep(fast)
        skew = skew_experiment(fast)
        payload = {"scaling": rows, "skew": skew}
        if fleet:
            payload["fleet"] = fleet_experiment(fast)
    save_artifact("fig9_cluster_scaling", payload, timer=tm)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--fleet", action="store_true",
                    help="32-node, 22k-request mixed-phase fleet scenario")
    args = ap.parse_args()
    main(fast=args.fast, fleet=args.fleet)
