"""Paper Figure 6: TTFT decomposition (queueing delay vs execution time),
4P4D-600W relative to 4P-750W/4D-450W at 1.5 QPS/GPU.

Validates: uniform-600W prefill is ~15% slower in execution, and that gap
compounds into a queueing-delay blow-up under load (backpressure).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, save_artifact, sim_run
from repro.configs import get_config
from repro.core.controller import policy_4p4d, policy_nonuniform
from repro.core.costmodel import MI300X, CostModel
from repro.core.power_model import mi300x
from repro.core.simulator import MAX_PREFILL_BATCH_TOKENS, Workload


def main(fast: bool = False):
    tm = Timer().start()
    cfg = get_config("llama31_8b")
    cm = CostModel(cfg, MI300X, mi300x())
    exec_600 = cm.prefill_time(MAX_PREFILL_BATCH_TOKENS, 600)
    exec_750 = cm.prefill_time(MAX_PREFILL_BATCH_TOKENS, 750)
    print(f"prefill exec time 600W vs 750W: +{(exec_600/exec_750-1)*100:.1f}% "
          f"(paper: ~15% slower)")
    out = {"exec_slowdown_600w": exec_600 / exec_750}
    n = 400 if fast else 1000
    for name, pol in [("4P4D-600W", policy_4p4d(600)),
                      ("4P-750W/4D-450W", policy_nonuniform(750, 450))]:
        wl = Workload.longbench_like(n, qps=1.5 * 8, seed=7)
        sim, s = sim_run(pol, wl)
        # queueing delay = TTFT minus pure execution estimate
        qdel = []
        for r in sim.records:
            if r.prefill_done is None:
                continue
            ex = cm.prefill_time(r.input_tokens,
                                 600 if "600" in name else 750)
            qdel.append(max(r.ttft - ex, 0.0))
        out[name] = {
            "p50_queue_delay_s": float(np.percentile(qdel, 50)),
            "p90_queue_delay_s": float(np.percentile(qdel, 90)),
            "p90_ttft_s": s.p90_ttft,
        }
        print(f"{name:18s} queue-delay p50={out[name]['p50_queue_delay_s']:.3f}s "
              f"p90={out[name]['p90_queue_delay_s']:.3f}s "
              f"(TTFT p90 {s.p90_ttft:.2f}s)")
    ratio = (out["4P4D-600W"]["p90_queue_delay_s"]
             / max(out["4P-750W/4D-450W"]["p90_queue_delay_s"], 1e-9))
    print(f"queueing-delay blow-up (600W/non-uniform): x{ratio:.1f} "
          f"(paper: 'increases dramatically')")
    save_artifact("fig6_queueing", out, timer=tm.stop())
    return out


if __name__ == "__main__":
    main()
