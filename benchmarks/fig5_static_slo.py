"""Paper Figure 5(a)/(b): static SLO attainment vs request rate, LongBench,
TTFT = 1 s, TPOT = 40 ms (a) / 25 ms (b). Also yields Figure 1's goodput
curves (goodput = SLO-meeting requests/s) and the QPS/W comparisons
(paper Section 5.1 headline numbers).
"""
from __future__ import annotations

from benchmarks.common import (NODE_BUDGET_W, Timer, save_artifact, sim_run)
from repro.core.controller import (StaticPolicy, policy_4p4d, policy_5p3d,
                                   policy_nonuniform)
from repro.core.simulator import Workload

QPS_PER_GPU = (0.75, 1.0, 1.25, 1.375, 1.5, 1.75)
N_REQ = 1200

CONFIGS = [
    ("coalesced-750W", StaticPolicy(4, 4, 750, 750, "coalesced-750W"), True, 6000.0),
    ("4P4D-750W", policy_4p4d(750), False, 6000.0),
    ("4P4D-600W", policy_4p4d(600), False, NODE_BUDGET_W),
    ("5P3D-600W", policy_5p3d(600), False, NODE_BUDGET_W),
    ("4P-750W/4D-450W", policy_nonuniform(750, 450), False, NODE_BUDGET_W),
    ("4P-675W/4D-525W", policy_nonuniform(675, 525), False, NODE_BUDGET_W),
]


def run(tpot_slo=0.040, n_req=N_REQ, rates=QPS_PER_GPU, seed=3):
    rows = []
    for qpg in rates:
        for name, pol, coal, budget in CONFIGS:
            wl = Workload.longbench_like(n_req, qps=qpg * 8, seed=seed,
                                         tpot_slo=tpot_slo)
            with Timer() as t:
                _, s = sim_run(pol, wl, budget=budget, coalesced=coal)
            rows.append({
                "qps_per_gpu": qpg, "config": name,
                "slo_attainment": s.slo_attainment,
                "goodput_rps": s.goodput_rps,
                "p90_ttft_s": s.p90_ttft, "p90_tpot_s": s.p90_tpot,
                "qps_per_kw": s.qps_per_kw,
                "avg_provisioned_w": s.avg_provisioned_w,
                "sim_wall_s": round(t.dt, 2),
            })
    return rows


def knee(rows, config, threshold=0.8):
    """Rate at which attainment crosses the threshold (linear interp)."""
    pts = sorted((r["qps_per_gpu"], r["slo_attainment"]) for r in rows
                 if r["config"] == config)
    prev = None
    for q, a in pts:
        if a < threshold:
            if prev is None:
                return q * threshold / max(a, 1e-9)   # below at first point
            q0, a0 = prev
            return q0 + (q - q0) * (a0 - threshold) / max(a0 - a, 1e-9)
        prev = (q, a)
    return pts[-1][0] if pts else 0.0


def main(fast: bool = False):
    tm = Timer().start()
    n = 500 if fast else N_REQ
    rates = (1.0, 1.25, 1.5) if fast else QPS_PER_GPU
    rows_a = run(0.040, n, rates)
    print(f"{'config':>18s} | " + " | ".join(f"{q:5.3f}" for q in rates))
    for name, *_ in CONFIGS:
        vals = [r["slo_attainment"] for r in rows_a if r["config"] == name]
        print(f"{name:>18s} | " + " | ".join(f"{v*100:5.1f}" for v in vals))
    k_coal = knee(rows_a, "coalesced-750W")
    k_750 = knee(rows_a, "4P4D-750W")
    k_600 = knee(rows_a, "4P4D-600W")
    k_nu = knee(rows_a, "4P-750W/4D-450W")
    if k_coal > 0:
        print(f"\n80% knees: coalesced-750={k_coal}  4P4D-750={k_750} "
              f"(x{k_750/k_coal:.2f})  4P4D-600={k_600} "
              f"(x{k_600/k_coal:.2f})  nonuniform={k_nu}")
        # QPS/W at the knee (provisioned node power: GPUs = 60% of node)
        qpw_nu = k_nu * 8 / (NODE_BUDGET_W / 0.6)
        qpw_coal = k_coal * 8 / (6000.0 / 0.6)
        print(f"QPS/W nonuniform vs coalesced-6000W: x{qpw_nu/qpw_coal:.2f}"
              f" (paper: 1.7x)")
    else:
        print(f"\n80% knees: coalesced-750=<{rates[0]}  4P4D-750={k_750}  "
              f"4P4D-600={k_600}  nonuniform={k_nu}")
    rows_b = run(0.025, n, rates)
    print("\nTPOT=25ms (Fig 5b):")
    for name, *_ in CONFIGS:
        vals = [r["slo_attainment"] for r in rows_b if r["config"] == name]
        print(f"{name:>18s} | " + " | ".join(f"{v*100:5.1f}" for v in vals))
    save_artifact("fig5_static_slo", {"tpot40": rows_a, "tpot25": rows_b},
                  timer=tm.stop())
    return rows_a, rows_b


if __name__ == "__main__":
    main()
