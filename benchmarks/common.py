"""Shared helpers for the benchmark harness (one module per paper figure)."""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import subprocess
import time

from repro.configs import get_config
from repro.core.controller import ControllerConfig
from repro.core.events import EventLoop
from repro.core.simulator import NodeSimulator

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

PAPER_MODEL = "llama31_8b"
NODE_BUDGET_W = 4800.0


def sim_run(policy, workload, *, budget=NODE_BUDGET_W, ctrl=None,
            coalesced=False, cfg_name=PAPER_MODEL, seed=0):
    cfg = get_config(cfg_name)
    sim = NodeSimulator(cfg, policy, node_budget_w=budget, ctrl_cfg=ctrl,
                        coalesced=coalesced, seed=seed)
    summary = sim.run(workload)
    return sim, summary


@functools.lru_cache(maxsize=1)
def git_sha() -> str:
    """Short SHA of the checkout the benchmark ran from (``unknown`` when
    git is unavailable, e.g. a source tarball)."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
            check=True).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def save_artifact(name: str, payload, timer: "Timer" = None):
    """Write one benchmark's JSON artifact, stamped with the git SHA it was
    produced from — perf/quality trajectories in the artifact history are
    attributable to commits. When a ``Timer`` is passed, the artifact gains
    ``wall_s`` and ``sim_events`` (simulator events dispatched while it
    ran) so the perf trajectory of every figure is recorded in the
    BENCH_*.json history, not just its derived metrics."""
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}.json")
    if not isinstance(payload, dict):
        payload = {"rows": payload}
    payload = {**payload, "git_sha": git_sha()}
    if timer is not None:
        payload = {**payload, "wall_s": round(timer.dt, 3),
                   "sim_events": timer.events}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def dyn_ctrl(tpot_slo=0.040, *, power=True, gpu=True, **kw) -> ControllerConfig:
    return dataclasses.replace(
        ControllerConfig(tpot_slo=tpot_slo), allow_power=power, allow_gpu=gpu,
        **kw) if kw else dataclasses.replace(
        ControllerConfig(tpot_slo=tpot_slo), allow_power=power, allow_gpu=gpu)


class Timer:
    """Wall-clock + simulator-event counter (process-wide dispatch total
    delta), so benchmark artifacts can report events/sec."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        self.ev0 = EventLoop.dispatched_total
        self.dt = 0.0
        self.events = 0
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
        self.events = EventLoop.dispatched_total - self.ev0

    # non-context-manager form, for mains that save mid-flow
    def start(self) -> "Timer":
        return self.__enter__()

    def stop(self) -> "Timer":
        self.__exit__()
        return self
