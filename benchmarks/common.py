"""Shared helpers for the benchmark harness (one module per paper figure)."""
from __future__ import annotations

import dataclasses
import json
import os
import time

from repro.configs import get_config
from repro.core.controller import (ControllerConfig, StaticPolicy,
                                   policy_4p4d, policy_5p3d,
                                   policy_nonuniform)
from repro.core.simulator import NodeSimulator, Workload

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

PAPER_MODEL = "llama31_8b"
NODE_BUDGET_W = 4800.0


def sim_run(policy, workload, *, budget=NODE_BUDGET_W, ctrl=None,
            coalesced=False, cfg_name=PAPER_MODEL, seed=0):
    cfg = get_config(cfg_name)
    sim = NodeSimulator(cfg, policy, node_budget_w=budget, ctrl_cfg=ctrl,
                        coalesced=coalesced, seed=seed)
    summary = sim.run(workload)
    return sim, summary


def save_artifact(name: str, payload):
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def dyn_ctrl(tpot_slo=0.040, *, power=True, gpu=True, **kw) -> ControllerConfig:
    return dataclasses.replace(
        ControllerConfig(tpot_slo=tpot_slo), allow_power=power, allow_gpu=gpu,
        **kw) if kw else dataclasses.replace(
        ControllerConfig(tpot_slo=tpot_slo), allow_power=power, allow_gpu=gpu)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
