"""Benchmark driver: one module per paper figure/table + roofline + kernels
+ the simulator-throughput benchmark (``simperf``).

Usage:
    PYTHONPATH=src python -m benchmarks.run [--fast] [--fleet] [--only fig5,...]
    PYTHONPATH=src python -m benchmarks.run --list

``--fleet`` additionally runs fig9's 32-node / 22k-request fleet scenario.
With ``--list`` (or an unknown ``--only`` target) the driver prints the
available targets with one-line descriptions instead of erroring bare.
Prints ``name,seconds,derived`` CSV lines at the end.

Targets (the README's figure-reproduction table is generated from these):

    fig4          prefill/decode latency vs per-GPU power cap (paper Fig. 4)
    fig5          static SLO attainment vs request rate (paper Fig. 5)
    fig6          TTFT decomposition: queueing vs execution (paper Fig. 6)
    fig7          SLO-scale sweep at fixed QPS/GPU (paper Fig. 7)
    fig8          dynamic RAPID on the two-phase Sonnet workload (paper Fig. 8-9)
    fig9cluster   1-8 node cluster scaling under a facility power budget
    fig10hetero   heterogeneous nodes + cluster-scale DynGPU role flips
    fig11fleet    elastic fleet under diurnal load and node churn
    fig12autoscale predictive autoscaling on a price/carbon tariff
    fig13chaos    chaos replay: graceful degradation vs naive handling
    fig14control  control-plane chaos: fail-safe vs oracle vs naive control
    fig15multitenant multi-tenant day: SLO classes + preemption + locality
    simperf       simulator event-throughput benchmark (perf gate)
    roofline      per-(arch x shape) roofline table from dry-run artifacts
    kernels       interpret-mode Pallas kernel microbenchmarks vs jnp oracles
    beyond        beyond-paper ablation studies
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = ("fig4", "fig5", "fig6", "fig7", "fig8", "fig9cluster",
          "fig10hetero", "fig11fleet", "fig12autoscale", "fig13chaos",
          "fig14control", "fig15multitenant", "simperf", "roofline",
          "kernels", "beyond")

# one-liners for --list / unknown-target help, same order as SUITES
DESCRIPTIONS = {
    "fig4": "prefill/decode latency vs per-GPU power cap (paper Fig. 4)",
    "fig5": "static SLO attainment vs request rate (paper Fig. 5)",
    "fig6": "TTFT decomposition: queueing vs execution (paper Fig. 6)",
    "fig7": "SLO-scale sweep at fixed QPS/GPU (paper Fig. 7)",
    "fig8": "dynamic RAPID on the two-phase Sonnet workload (paper Fig. 8-9)",
    "fig9cluster": "1-8 node cluster scaling under a facility power budget",
    "fig10hetero": "heterogeneous nodes + cluster-scale DynGPU role flips",
    "fig11fleet": "elastic fleet under diurnal load and node churn",
    "fig12autoscale": "predictive autoscaling on a price/carbon tariff",
    "fig13chaos": "chaos replay: graceful degradation vs naive handling",
    "fig14control": "control-plane chaos: fail-safe vs oracle vs naive control",
    "fig15multitenant": "multi-tenant day: SLO classes + preemption + locality",
    "simperf": "simulator event-throughput benchmark (perf gate)",
    "roofline": "per-(arch x shape) roofline table from dry-run artifacts",
    "kernels": "interpret-mode Pallas kernel microbenchmarks vs jnp oracles",
    "beyond": "beyond-paper ablation studies",
}


def print_targets(header: str = "Available targets:") -> None:
    print(header)
    for name in SUITES:
        print(f"  {name:15s} {DESCRIPTIONS[name]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced request counts / rate grids")
    ap.add_argument("--fleet", action="store_true",
                    help="include fig9's 32-node fleet scenario")
    ap.add_argument("--list", action="store_true",
                    help="print available targets and exit")
    ap.add_argument("--only", default=None,
                    help="comma-separated target subset (see --list)")
    ap.add_argument("--seed", type=int, default=None,
                    help="scenario seed for the seeded targets "
                         "(fig13chaos, fig14control, fig15multitenant); "
                         "default: each module's built-in seed")
    args = ap.parse_args()
    if args.list:
        print_targets()
        return
    only = set(args.only.split(",")) if args.only else set(SUITES)
    unknown = only - set(SUITES)
    if unknown:
        print_targets(f"Unknown target(s): {sorted(unknown)}. "
                      f"Available targets:")
        raise SystemExit(2)

    from benchmarks import (beyond_ablations, fig4_power_curves,
                            fig5_static_slo, fig6_queueing, fig7_slo_scaling,
                            fig8_dynamic, fig9_cluster_scaling,
                            fig10_hetero_dyngpu, fig11_elastic_fleet,
                            fig12_autoscale_tariff, fig13_chaos,
                            fig14_control_chaos, fig15_multitenant,
                            kernels_bench, roofline, sim_throughput)
    mods = {
        "fig4": fig4_power_curves, "fig5": fig5_static_slo,
        "fig6": fig6_queueing, "fig7": fig7_slo_scaling,
        "fig8": fig8_dynamic, "fig9cluster": fig9_cluster_scaling,
        "fig10hetero": fig10_hetero_dyngpu,
        "fig11fleet": fig11_elastic_fleet,
        "fig12autoscale": fig12_autoscale_tariff, "fig13chaos": fig13_chaos,
        "fig14control": fig14_control_chaos,
        "fig15multitenant": fig15_multitenant,
        "simperf": sim_throughput,
        "roofline": roofline, "kernels": kernels_bench,
        "beyond": beyond_ablations,
    }
    results = []
    failed = []
    for name in SUITES:
        if name not in only:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.perf_counter()
        try:
            kw = {"fleet": True} if (args.fleet and name == "fig9cluster") \
                else {}
            if args.seed is not None and name in ("fig13chaos",
                                                  "fig14control",
                                                  "fig15multitenant"):
                kw["seed"] = args.seed
            out = mods[name].main(fast=args.fast, **kw)
            n = len(out) if hasattr(out, "__len__") else 1
            results.append((name, time.perf_counter() - t0, n))
        except Exception:
            traceback.print_exc()
            failed.append(name)
    print("\nname,seconds,derived")
    for name, dt, n in results:
        print(f"{name},{dt:.1f},{n}")
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
