"""Benchmark driver: one module per paper figure/table + roofline + kernels
+ the simulator-throughput benchmark (``simperf``).

Usage:
    PYTHONPATH=src python -m benchmarks.run [--fast] [--fleet] [--only fig5,...]

``--fleet`` additionally runs fig9's 32-node / 22k-request fleet scenario.
Prints ``name,seconds,derived`` CSV lines at the end.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = ("fig4", "fig5", "fig6", "fig7", "fig8", "fig9cluster",
          "fig10hetero", "fig11fleet", "simperf", "roofline", "kernels",
          "beyond")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced request counts / rate grids")
    ap.add_argument("--fleet", action="store_true",
                    help="include fig9's 32-node fleet scenario")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)

    from benchmarks import (beyond_ablations, fig4_power_curves,
                            fig5_static_slo, fig6_queueing, fig7_slo_scaling,
                            fig8_dynamic, fig9_cluster_scaling,
                            fig10_hetero_dyngpu, fig11_elastic_fleet,
                            kernels_bench, roofline, sim_throughput)
    mods = {
        "fig4": fig4_power_curves, "fig5": fig5_static_slo,
        "fig6": fig6_queueing, "fig7": fig7_slo_scaling,
        "fig8": fig8_dynamic, "fig9cluster": fig9_cluster_scaling,
        "fig10hetero": fig10_hetero_dyngpu,
        "fig11fleet": fig11_elastic_fleet, "simperf": sim_throughput,
        "roofline": roofline, "kernels": kernels_bench,
        "beyond": beyond_ablations,
    }
    results = []
    failed = []
    for name in SUITES:
        if name not in only:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.perf_counter()
        try:
            kw = {"fleet": True} if (args.fleet and name == "fig9cluster") \
                else {}
            out = mods[name].main(fast=args.fast, **kw)
            n = len(out) if hasattr(out, "__len__") else 1
            results.append((name, time.perf_counter() - t0, n))
        except Exception:
            traceback.print_exc()
            failed.append(name)
    print("\nname,seconds,derived")
    for name, dt, n in results:
        print(f"{name},{dt:.1f},{n}")
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
