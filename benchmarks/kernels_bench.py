"""Per-kernel microbenchmark: wall time of the interpret-mode Pallas kernels
vs their jnp oracles on CPU (correctness-oriented; TPU timings require real
hardware — block shapes and VMEM claims are validated structurally).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, save_artifact
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.rglru_scan.ops import rglru_scan


def timeit(fn, *args, n=3, **kw):
    fn(*args, **kw).block_until_ready() if hasattr(
        fn(*args, **kw), "block_until_ready") else None
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def main(fast: bool = False):
    tm = Timer().start()
    key = jax.random.key(0)
    rows = []
    # flash attention
    B, S, H, hd = 1, 256, 4, 64
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    us_p = timeit(flash_attention, q, q, q, impl="pallas")
    us_r = timeit(flash_attention, q, q, q, impl="ref")
    rows.append(("flash_attention", us_p, us_r))
    # decode attention
    q1 = jax.random.normal(key, (2, 8, 64), jnp.float32)
    kc = jax.random.normal(key, (2, 1024, 2, 64), jnp.float32)
    us_p = timeit(decode_attention, q1, kc, kc, 900, impl="pallas")
    us_r = timeit(decode_attention, q1, kc, kc, 900, impl="ref")
    rows.append(("decode_attention", us_p, us_r))
    # rglru
    la = -jnp.abs(jax.random.normal(key, (2, 512, 256))) * 0.1
    x = jax.random.normal(key, (2, 512, 256))
    h0 = jnp.zeros((2, 256))
    us_p = timeit(rglru_scan, la, x, h0, impl="pallas")
    us_r = timeit(rglru_scan, la, x, h0, impl="ref")
    rows.append(("rglru_scan", us_p, us_r))
    for name, us_p, us_r in rows:
        print(f"{name:18s} pallas(interpret) {us_p:10.0f}us  jnp-ref {us_r:10.0f}us")
    save_artifact("kernels_bench", timer=tm.stop(), payload=[
        {"kernel": n, "pallas_interpret_us": p, "ref_us": r}
        for n, p, r in rows])
    return rows


if __name__ == "__main__":
    main()
