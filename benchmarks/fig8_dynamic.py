"""Paper Figure 8 + 9: dynamic RAPID on the two-phase Sonnet workload
(prefill-heavy 8k/128 then decode-heavy 500/500, TPOT SLO 40ms -> 20ms).

Validates: DynGPU+DynPower best overall; DynPower alone converges to the
static non-uniform optimum; up to ~2x SLO attainment over static at peak.
Also dumps the Figure-9 time series (per-GPU caps + roles).

NOTE (--fast): at reduced n DynGPU-DynPower lands BELOW plain static
(e.g. x0.47) — the controller pays its role-flip drains right as the phase
ends and never amortizes them. Seed behavior at small n, not a regression;
the full run matches the paper ordering (see EXPERIMENTS.md §Simulator
performance).
"""
from __future__ import annotations

from benchmarks.common import Timer, dyn_ctrl, save_artifact, sim_run
from repro.core.controller import (policy_4p4d, policy_5p3d,
                                   policy_nonuniform)
from repro.core.simulator import Workload

QPS = 6.5          # ~0.8 QPS/GPU: the 8k-prompt phase saturates our
                   # calibrated node near 1.0 (see EXPERIMENTS.md)


def configs():
    return [
        ("4P4D-600W", policy_4p4d(600), None),
        ("5P3D-600W", policy_5p3d(600), None),
        ("4P-750W/4D-450W", policy_nonuniform(750, 450), None),
        ("4P4D-DynPower", policy_4p4d(600), dyn_ctrl(gpu=False)),
        ("DynGPU-600W", policy_4p4d(600), dyn_ctrl(power=False)),
        ("DynGPU-DynPower", policy_4p4d(600), dyn_ctrl()),
    ]


def main(fast: bool = False):
    tm = Timer().start()
    n = 400 if fast else 600
    rows = []
    traces = {}
    for name, pol, ctrl in configs():
        wl = Workload.sonnet_phases(QPS, seed=5, n1=n, n2=n)
        sim, s = sim_run(pol, wl, ctrl=ctrl)
        rows.append({"config": name, "slo_attainment": s.slo_attainment,
                     "goodput_rps": s.goodput_rps, "p90_ttft_s": s.p90_ttft,
                     "p90_tpot_s": s.p90_tpot, "qps_per_kw": s.qps_per_kw,
                     "moves": len(sim.ctrl.trace) if sim.ctrl else 0})
        print(f"{name:18s} att={s.slo_attainment*100:5.1f}%  {s.row()}")
        if ctrl is not None:
            traces[name] = {
                "caps": [(t, caps) for t, caps, _ in sim.trace_caps[::4]],
                "roles": [(t, roles.count("prefill"), roles.count("decode"))
                          for t, _, roles in sim.trace_caps[::4]],
                "moves": sim.ctrl.trace,
            }
    att = {r["config"]: r["slo_attainment"] for r in rows}
    best_static = max(att["4P4D-600W"], att["5P3D-600W"])
    print(f"\nDynGPU-DynPower vs best plain static: "
          f"x{att['DynGPU-DynPower']/max(best_static,1e-9):.2f} (paper: up to 2x)")
    print(f"DynPower vs static non-uniform: {att['4P4D-DynPower']*100:.1f}% vs "
          f"{att['4P-750W/4D-450W']*100:.1f}% (paper: converges to same)")
    save_artifact("fig8_dynamic", {"rows": rows, "fig9_traces": traces},
                  timer=tm.stop())
    return rows


if __name__ == "__main__":
    main()
