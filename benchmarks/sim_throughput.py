"""Simulator-throughput benchmark: the perf trajectory of the sim core.

Unlike the fig* modules (which measure the *modeled system*), this measures
the *simulator itself* on two fixed workloads, under both fidelities:

  single-node   one 8-GPU node, LongBench-like traffic, DynPower controller
  cluster       8 nodes under DynPower + cluster budget shifting, a
                long-generation fleet mix (the regime fig9 --fleet runs in)

For each (scenario, fidelity) it reports wall seconds, dispatched events,
simulated decode iterations, events/sec, decode-iters/sec, and simulated
seconds per wall second. The macro arm must beat the per-iteration arm by
``MIN_CLUSTER_SPEEDUP`` on the cluster scenario in full mode, and both arms
must produce identical goodput summaries (the full golden-equivalence test
lives in tests/test_sim_macrostep.py).

CI runs ``--fast`` with ``--min-iters-per-sec`` as an order-of-magnitude
regression floor (generous: shared runners are slow; the floor catches a
10x collapse, not noise).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

from benchmarks.common import Timer, dyn_ctrl, save_artifact
from repro.configs import get_config
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import StaticPolicy, policy_4p4d
from repro.core.simulator import NodeSimulator, Workload

MIN_CLUSTER_SPEEDUP = 5.0       # acceptance floor, full mode only

CFG = "llama31_8b"


def _node_run(fidelity: str, fast: bool):
    n = 150 if fast else 600
    wl = Workload.longbench_like(n, qps=9.0, seed=3)
    sim = NodeSimulator(get_config(CFG), policy_4p4d(600),
                        ctrl_cfg=dyn_ctrl(gpu=False), seed=3,
                        fidelity=fidelity)
    t0, c0 = time.perf_counter(), time.process_time()
    s = sim.run(wl)
    wall, cpu = time.perf_counter() - t0, time.process_time() - c0
    return wall, cpu, sim.loop.dispatched, sim.decode_iters, sim.loop.now, s


def _cluster_run(fidelity: str, fast: bool):
    n_nodes = 2 if fast else 8
    n = 300 if fast else 2000
    # long-generation fleet regime: a 2P/6D split spreads decode over many
    # small continuous batches, so per-request decode runs are long and
    # iteration events dominate — the shape fig9 --fleet studies, and the
    # worst case for a per-iteration event core (~1.1M decode iterations)
    wl = Workload.uniform(n, qps=0.7 * n_nodes, in_tokens=2000,
                          out_tokens=1500, seed=3)
    cs = ClusterSimulator(get_config(CFG), StaticPolicy(2, 6, 500, 500),
                          n_nodes, node_budget_w=4000.0,
                          ctrl_cfg=dyn_ctrl(gpu=False),
                          cluster_cfg=ClusterConfig(allow_shift=True),
                          seed=3, fidelity=fidelity)
    t0, c0 = time.perf_counter(), time.process_time()
    s = cs.run(wl)
    wall, cpu = time.perf_counter() - t0, time.process_time() - c0
    iters = sum(nd.decode_iters for nd in cs.nodes)
    return wall, cpu, cs.loop.dispatched, iters, cs.loop.now, s


def _row(name, fidelity, wall, cpu, events, iters, sim_s, summary):
    row = {
        "scenario": name, "fidelity": fidelity,
        "wall_s": round(wall, 4),
        "cpu_s": round(cpu, 4),
        "events": events,
        "decode_iters": iters,
        "sim_s": round(sim_s, 2),
        "events_per_s": round(events / wall, 1),
        "iters_per_s": round(iters / wall, 1),
        "sim_s_per_wall_s": round(sim_s / wall, 1),
        "slo_attainment": summary.slo_attainment,
        "goodput_rps": summary.goodput_rps,
    }
    print(f"{name:12s} {fidelity:5s} wall {wall:7.2f}s  "
          f"events {events:8d}  iters/s {row['iters_per_s']:10,.0f}  "
          f"sim-s/wall-s {row['sim_s_per_wall_s']:7.1f}")
    return row


def main(fast: bool = False, min_iters_per_sec: float = 0.0):
    rows = []
    speedups = {}
    with Timer() as tm:
        for name, runner in (("single-node", _node_run),
                             ("cluster", _cluster_run)):
            per_fid = {}
            for fidelity in ("iter", "macro"):
                wall, cpu, events, iters, sim_s, s = runner(fidelity, fast)
                per_fid[fidelity] = (cpu, s)
                rows.append(_row(name, fidelity, wall, cpu, events, iters,
                                 sim_s, s))
            # same-workload arms must agree exactly — a standing check on
            # macro-step equivalence in every benchmark run (the full
            # per-request golden test lives in tests/test_sim_macrostep.py)
            assert dataclasses.asdict(per_fid["iter"][1]) == \
                dataclasses.asdict(per_fid["macro"][1]), \
                f"{name}: macro summary diverged from per-iteration fidelity"
            # speedup on CPU time: robust against container descheduling
            # noise, which otherwise dominates the short macro arm
            speedups[name] = per_fid["iter"][0] / per_fid["macro"][0]
            print(f"{name:12s} macro speedup {speedups[name]:.2f}x")
    if not fast:
        assert speedups["cluster"] >= MIN_CLUSTER_SPEEDUP, \
            (f"macro-stepping must give >= {MIN_CLUSTER_SPEEDUP}x on the "
             f"cluster workload, got {speedups['cluster']:.2f}x")
    macro_cluster = next(r for r in rows
                         if r["scenario"] == "cluster"
                         and r["fidelity"] == "macro")
    if min_iters_per_sec:
        assert macro_cluster["iters_per_s"] >= min_iters_per_sec, \
            (f"simulated decode iters/s regressed by an order of magnitude: "
             f"{macro_cluster['iters_per_s']:.0f} < {min_iters_per_sec:.0f}")
    payload = {"rows": rows,
               "speedup": {k: round(v, 2) for k, v in speedups.items()}}
    save_artifact("sim_throughput", payload, timer=tm)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--min-iters-per-sec", type=float, default=0.0,
                    help="assert a floor on macro cluster decode-iters/sec "
                         "(generous; catches order-of-magnitude regressions)")
    args = ap.parse_args()
    main(fast=args.fast, min_iters_per_sec=args.min_iters_per_sec)
