"""Cluster-scale DynGPU on heterogeneous nodes (goes beyond the paper):
{static roles, DynPower, DynPower+DynGPU} x {homogeneous, heterogeneous}.

The scenario composes the two skews the cluster layer exists for:

  * hardware skew — node 0 is an MI300X node, node 1 (hetero arms) an H100
    node whose 4-GPU prefill pool is ~20% slower on an 8k prompt, so the
    static role split that fits one vendor starves on the other;
  * role skew — the routed stream is prefill-heavy (8k in / 128 out, 2 s
    TTFT) at the fig9 operating point of 4.0 QPS *per node* (between a
    4-prefill-GPU MI300X node's capacity knees at 600 W and 750 W caps,
    see EXPERIMENTS.md §Cluster), while node 0 additionally serves a pinned
    decode-heavy stream (500/500, 30 ms TPOT) that keeps its decode GPUs
    honest.

Under that load the cluster's *static-role* prefill capacity is below
demand, and both nodes are stressed, so the budget pool is exhausted —
watts alone cannot fix it (the DynPower arm proves it). Only cluster-scale
MoveGPU — the coordinator flipping decode GPUs to prefill on the
least-stressed node, with the router re-weighting by effective role
capacity — recovers the SLO. The facility power invariant is asserted on
every coordinator tick and across every in-flight role-flip drain; this
driver re-checks the recorded budget trace and requires the DynGPU arm to
be at least as good as static roles on the skewed heterogeneous scenario.
"""
from __future__ import annotations

from benchmarks.common import Timer, dyn_ctrl, save_artifact
from repro.configs import get_config
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import policy_4p4d
from repro.core.costmodel import H100, MI300X
from repro.core.simulator import Workload

NODE_BUDGET_W = 4000.0          # power-constrained nodes (fig9 regime)
POLICY = policy_4p4d(500)       # 8 x 500 W fits the 4000 W node budget
QPS_PER_NODE = 4.0              # routed prefill-heavy operating point
TTFT_SLO_S = 2.0

HARDWARE = {
    "homogeneous": [MI300X, MI300X],
    "heterogeneous": [MI300X, H100],
}


def regimes():
    dyn = dyn_ctrl(gpu=False, ttft_slo=TTFT_SLO_S)
    return [
        ("static", None, ClusterConfig(allow_shift=False)),
        ("DynPower", dyn, ClusterConfig(allow_shift=True)),
        ("DynPower+DynGPU", dyn,
         ClusterConfig(allow_shift=True, allow_gpu_move=True)),
    ]


def _run(specs, ctrl, ccfg, n, seed):
    cs = ClusterSimulator(get_config("llama31_8b"), POLICY, len(specs),
                          node_budget_w=NODE_BUDGET_W, ctrl_cfg=ctrl,
                          cluster_cfg=ccfg, gpu_specs=specs, seed=7)
    routed = Workload.uniform(n, qps=QPS_PER_NODE * len(specs),
                              in_tokens=8192, out_tokens=128, seed=seed,
                              ttft_slo=TTFT_SLO_S, tpot_slo=0.040)
    pinned = {0: Workload.uniform(n // 2, qps=2.0, in_tokens=500,
                                  out_tokens=500, seed=seed + 1,
                                  tpot_slo=0.030)}
    s = cs.run(routed, pinned=pinned)
    for t, budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6, (t, budgets, total)
    return cs, s


def sweep(fast: bool):
    n = 120 if fast else 400
    rows = []
    att = {}
    for hw_name, specs in HARDWARE.items():
        for reg_name, ctrl, ccfg in regimes():
            cs, s = _run(specs, ctrl, ccfg, n, seed=5)
            att[(hw_name, reg_name)] = s.slo_attainment
            rows.append({
                "hardware": hw_name, "regime": reg_name,
                "slo_attainment": s.slo_attainment,
                "goodput_rps": s.goodput_rps,
                "p90_ttft_s": s.p90_ttft, "p90_tpot_s": s.p90_tpot,
                "qps_per_kw": s.qps_per_kw,
                "budget_shifts": len(cs.shift_trace),
                "role_flips": len(cs.flip_trace),
                "final_roles": ["".join(g.role[0].upper() for g in nd.gpus)
                                for nd in cs.nodes],
                "final_budgets": [nd.pm.budget for nd in cs.nodes],
            })
            print(f"{hw_name:13s} {reg_name:15s} "
                  f"att={s.slo_attainment*100:5.1f}%  "
                  f"TTFT p90 {s.p90_ttft:5.2f}s  "
                  f"shifts={len(cs.shift_trace)}  "
                  f"flips={len(cs.flip_trace)}  "
                  f"roles={rows[-1]['final_roles']}")
    gain = att[("heterogeneous", "DynPower+DynGPU")] - \
        att[("heterogeneous", "static")]
    print(f"\nhetero DynGPU+DynPower vs static roles: "
          f"{att[('heterogeneous', 'DynPower+DynGPU')]*100:.1f}% vs "
          f"{att[('heterogeneous', 'static')]*100:.1f}%  (+{gain*100:.1f}pp)")
    assert att[("heterogeneous", "DynPower+DynGPU")] >= \
        att[("heterogeneous", "static")], \
        "cluster DynGPU must not lose to static roles on the skewed " \
        "heterogeneous scenario"
    return rows


def main(fast: bool = False):
    tm = Timer().start()
    rows = sweep(fast)
    save_artifact("fig10_hetero_dyngpu", {"sweep": rows}, timer=tm.stop())
    return rows


if __name__ == "__main__":
    main()
