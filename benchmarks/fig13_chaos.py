"""Chaos replay: graceful degradation vs naive handling (beyond the paper).

One seeded fault schedule — a facility power emergency with a traffic
surge landing inside it, a correlated 2-node rack failure, and a lossy
migration link under a graceful drain — replayed bit-identically against
two fleets under the same facility cap:

  naive     the PR-5-era failure story: migrations get one attempt (a
            link fault means immediate KV loss and a from-scratch
            re-prefill), and the router admits everything — overload
            queues every request into SLO violation;
  degraded  the full degradation ladder (core/chaos.py docstring):
            failed transfers retry with capped exponential backoff
            against a per-request deadline before falling back to
            requeue-with-KV-loss, and SLO-aware admission control sheds
            or defers the lowest-value requests when projected latency
            violates the SLO fleet-wide.

Both arms absorb the emergency the same way (force-throttle to the
slashed limit, source-before-sink; restore on clear) and re-level the
rack failure's pooled watts in ONE facility pass — the arms differ only
in the retry and admission policies under test.

Asserted here (fast mode too — this is the CI ``chaos-smoke`` gate):

* the degraded arm's SLO attainment is >= the naive arm's under the
  identical fault schedule and facility cap;
* two runs of the same arm with the same seed produce bit-identical
  per-request records (arrival/prefill/finish/energy/shed fingerprints)
  — chaos is deterministic, not "flaky on purpose";
* the facility invariant holds over the recorded budget trace, and the
  emergency trace shows the full begin -> enforced -> end ladder.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, dyn_ctrl, save_artifact
from repro.configs import get_config
from repro.core.chaos import ChaosConfig, ChaosEngine
from repro.core.cluster import AdmissionConfig, ClusterConfig, ClusterSimulator
from repro.core.controller import policy_4p4d
from repro.core.fleet import FleetConfig, FleetManager

N_NODES = 4
NODE_BUDGET_W = 4000.0          # power-constrained nodes (fig9 regime)
POLICY = policy_4p4d(500)
TTFT_SLO_S = 2.0
TPOT_SLO_S = 0.040
BASE_QPS = 8.0                  # steady arrivals; the surge rides on top
EMERGENCY_FRAC = 0.55           # facility cap slashed to 55% of nameplate


def n_requests(fast: bool) -> int:
    return 160 if fast else 480


def fault_schedule(fast: bool):
    """Faults pinned to the workload's expected span ``T``: the emergency
    opens a quarter in and the surge lands just inside it (scarcity meets
    demand), the rack failure hits after the restore while the backlog
    drains, and the lossy drain runs near the tail."""
    T = n_requests(fast) / BASE_QPS
    return {
        "t_emergency": 0.25 * T, "emergency_dur": 0.30 * T,
        "t_surge": 0.27 * T,
        "n_surge": 40 if fast else 120, "surge_qps": 20.0,
        "t_rack_fail": 0.62 * T, "rack": (2, 3),
        "t_rack_rejoin": 0.78 * T,
        "t_drain": 0.88 * T, "drain_node": 1,
        "link_fault_s": 1.0,
        "t_drain_rejoin": 0.98 * T,
    }


def baseline(fast: bool, seed: int):
    """Steady Poisson arrivals (drawn at build time — the run itself is
    deterministic), identical across arms."""
    from repro.core.simulator import Workload
    n = n_requests(fast)
    t = Workload.poisson_arrivals(n, BASE_QPS, np.random.default_rng(seed))
    return Workload([(float(t[i]), 4096, 256, TTFT_SLO_S, TPOT_SLO_S)
                     for i in range(n)], name="chaos_baseline")


def _run(degraded: bool, fast: bool, seed: int = 3):
    cs = ClusterSimulator(
        get_config("llama31_8b"), POLICY, N_NODES,
        node_budget_w=NODE_BUDGET_W,
        ctrl_cfg=dyn_ctrl(gpu=False, ttft_slo=TTFT_SLO_S),
        cluster_cfg=ClusterConfig(allow_shift=True), seed=7,
        admission=AdmissionConfig(slo_aware=True) if degraded else None)
    fm = FleetManager(cs, FleetConfig(
        migrate_max_retries=4 if degraded else 0))
    ch = ChaosEngine(fm, ChaosConfig(seed=seed))
    f = fault_schedule(fast)
    ch.schedule_power_emergency(f["t_emergency"], EMERGENCY_FRAC,
                                f["emergency_dur"])
    ch.schedule_surge(f["t_surge"], f["n_surge"], qps=f["surge_qps"],
                      input_tokens=4096, output_tokens=256,
                      ttft_slo=TTFT_SLO_S, tpot_slo=TPOT_SLO_S)
    ch.schedule_rack_failure(f["t_rack_fail"], list(f["rack"]))
    for i, nid in enumerate(f["rack"]):
        fm.schedule_join(f["t_rack_rejoin"] + 0.5 * i, nid)
    ch.schedule_link_fault(f["t_drain"], f["drain_node"],
                           f["link_fault_s"], mode="fail")
    fm.schedule_leave(f["t_drain"], f["drain_node"])
    fm.schedule_join(f["t_drain_rejoin"], f["drain_node"])
    s = cs.run(baseline(fast, seed))
    # facility invariant over the whole run, emergency window included:
    # committed node budgets never exceed the nameplate facility budget
    for t, budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6, (t, budgets, total)
    kinds = [k for _, k, _ in fm.emergency_trace]
    assert kinds == ["begin", "enforced", "end"], fm.emergency_trace
    assert all(np.isfinite(r.energy_j) and r.energy_j >= 0
               for r in cs.records), "every record must carry finite joules"
    return cs, fm, s


def fingerprint(cs):
    """Per-request record tuple set — the bit-identity gate."""
    return [(r.rid, r.arrival, r.prefill_done, r.finish, r.energy_j,
             r.shed_t) for r in cs.records]


def sweep(fast: bool, seed: int = 3):
    rows = []
    att = {}
    for name, degraded in (("naive", False), ("degraded", True)):
        cs, fm, s = _run(degraded, fast, seed)
        att[name] = s.slo_attainment
        rows.append({
            "arm": name,
            "slo_attainment": s.slo_attainment,
            "goodput_rps": s.goodput_rps,
            "p90_ttft_s": s.p90_ttft, "p90_tpot_s": s.p90_tpot,
            "n_shed": s.n_shed, "shed_energy_j": s.shed_energy_j,
            "total_energy_j": s.total_energy_j,
            "energy_per_good_token_j": s.energy_per_good_token_j,
            "migrations": len(fm.migration_trace),
            "retries": len(fm.retry_trace),
            "kv_losses": len(fm.kv_loss_trace),
            "emergency": [(round(t, 2), k, round(w, 1))
                          for t, k, w in fm.emergency_trace],
        })
        print(f"{name:9s} att={s.slo_attainment*100:5.1f}%  "
              f"TTFT p90 {s.p90_ttft:5.2f}s  "
              f"goodput {s.goodput_rps:5.2f} req/s  "
              f"shed={s.n_shed} retries={len(fm.retry_trace)} "
              f"kv_loss={len(fm.kv_loss_trace)}")
    gain = att["degraded"] - att["naive"]
    print(f"\ndegraded vs naive under the identical fault schedule: "
          f"{att['degraded']*100:.1f}% vs {att['naive']*100:.1f}% "
          f"(+{gain*100:.1f}pp)")
    assert att["degraded"] >= att["naive"], \
        "retry + SLO-aware shedding must not lose to the naive failure " \
        "story under the same fault schedule and facility cap"
    # determinism gate: same arm, same seed, bit-identical records
    cs_a, _, _ = _run(True, fast, seed)
    cs_b, _, _ = _run(True, fast, seed)
    assert fingerprint(cs_a) == fingerprint(cs_b), \
        "chaos runs must be bit-identical per seed"
    print("rerun determinism: bit-identical per-request records  OK")
    return rows


def main(fast: bool = False, seed: int = 3):
    tm = Timer().start()
    rows = sweep(fast, seed)
    save_artifact("fig13_chaos", {"sweep": rows, "seed": seed},
                  timer=tm.stop())
    return rows


if __name__ == "__main__":
    main()
