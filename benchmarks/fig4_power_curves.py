"""Paper Figure 4: (a) prefill TTFT and (b) decode TPOT vs per-GPU power cap
(400-750 W, 50 W steps), batch sizes 1-32; (c) power-cap enforcement latency
(source-before-sink timing from the PowerManager).

Validates: prefill ~1.8x speedup at 750 W vs 400 W; decode flattening
beyond ~600 W (1.3-1.5x); cap changes enforce in O(100 ms).
"""
from __future__ import annotations

from benchmarks.common import Timer, save_artifact
from repro.configs import get_config
from repro.core.costmodel import MI300X, CostModel
from repro.core.power_manager import PowerManager, SimulatedSMI
from repro.core.power_model import mi300x

CAPS = list(range(400, 751, 50))


def main(fast: bool = False):
    tm = Timer().start()
    cfg = get_config("llama31_8b")
    cm = CostModel(cfg, MI300X, mi300x())
    rows = []
    print("cap_w | prefill speedup (4096 tok) | decode speedup (b=32, ctx=4k)")
    t_p400 = cm.prefill_time(4096, 400)
    t_d400 = cm.decode_step_time(32, 4096, 400)
    for cap in CAPS:
        sp = t_p400 / cm.prefill_time(4096, cap)
        sd = t_d400 / cm.decode_step_time(32, 4096, cap)
        rows.append({"cap_w": cap, "prefill_speedup": sp, "decode_speedup": sd})
        print(f"{cap:5d} | {sp:26.3f} | {sd:28.3f}")
    sp750, sd750 = rows[-1]["prefill_speedup"], rows[-1]["decode_speedup"]
    print(f"\nprefill 750W/400W = {sp750:.2f}x (paper ~1.8x for 1.87x power)")
    print(f"decode  750W/400W = {sd750:.2f}x (paper 1.3-1.5x)")
    sd600 = next(r for r in rows if r["cap_w"] == 600)["decode_speedup"]
    print(f"decode gain beyond 600W: {(sd750/sd600-1)*100:.1f}% "
          f"(paper: flattens)")

    # Fig 4c: enforcement latency + source-before-sink ordering
    pm = PowerManager(8, 4800.0, backend=SimulatedSMI(0.3),
                      initial_caps=[600.0] * 8)
    t_ready, freed = pm.shift(0.0, src=[4, 5, 6, 7], dst=[0, 1, 2, 3],
                              watts_per_gpu=150.0)
    assert t_ready == 0.3 and freed == 600.0
    pm.tick(0.1)
    caps_during = list(pm.effective)
    pm.tick(0.3)
    pm.apply_raise(0.3, [0, 1, 2, 3], freed)
    caps_after = list(pm.effective)
    print(f"\ncap enforcement: lower commanded at t=0, in force at t={t_ready}s; "
          f"sinks raised only after")
    print(f"  during ramp (t=0.1): {caps_during} (sum {sum(caps_during):.0f})")
    print(f"  after raise (t=0.3): {caps_after} (sum {sum(caps_after):.0f})")
    assert sum(caps_after) <= 4800.0 + 1e-6
    save_artifact("fig4_power_curves", timer=tm.stop(), payload={"curves": rows,
                                        "enforce_latency_s": 0.3})
    return rows


if __name__ == "__main__":
    main()
