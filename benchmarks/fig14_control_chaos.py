"""Control-plane chaos: fail-safe headless mode vs oracle control (beyond
the paper).

One seeded schedule combining the three control-plane faults from
``core/telemetry.py`` + ``core/chaos.py`` — a telemetry freeze, a
coordinator/autoscaler crash window, and a physical node death landing
INSIDE the crash (with a traffic surge riding on top) — replayed against
three fleets under the same facility cap:

  oracle    the PR-8-era control story as an upper bound: perfect fresh
            telemetry, the controller never dies, and node failure is
            detected the instant it happens (``schedule_rack_failure``);
  naive     controllers keep acting through the faults: stale/frozen
            telemetry is trusted (``act_on_stale=True``), there is no
            admission control, and the headless window admits everything
            round-robin;
  failsafe  the full fault-tolerance ladder: staleness holds (the
            coordinator and autoscaler refuse to act past the staleness
            bound), SLO-aware local admission while headless, heartbeat
            failure detection (suspected -> dead, requeue at DETECTION
            time, not death time), and epoch-fenced budget grants.

All three arms face the identical data-plane faults (surge + node 3
death + rejoin); only naive and failsafe face the control-plane faults
(freeze + crash) — oracle shows what perfect control would buy.

Asserted here (fast mode too — this is the CI ``chaos-smoke`` gate):

* the failsafe arm's SLO attainment is >= the naive arm's under the
  identical fault schedule and facility cap;
* committed node budgets never exceed the facility nameplate in ANY arm
  over the full budget trace — headless windows included (and under
  ``RAPID_SANITIZE=1`` the per-dispatch headless + epoch-fence checks
  run as well);
* the crash trace shows the full crash -> restart ladder and the
  heartbeat detector's suspected -> dead_detected ladder fires for the
  dead node (failsafe arm);
* the coordinator actually HELD on stale telemetry during the freeze
  (hold trace non-empty in both faulted arms — naive records the holds
  it refused to take);
* two runs of the failsafe arm with the same seed produce bit-identical
  per-request records — control-plane chaos is deterministic too.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, dyn_ctrl, save_artifact
from repro.configs import get_config
from repro.core.chaos import ChaosConfig, ChaosEngine
from repro.core.cluster import AdmissionConfig, ClusterConfig, ClusterSimulator
from repro.core.controller import policy_4p4d
from repro.core.fleet import FleetConfig, FleetManager
from repro.core.telemetry import (HeartbeatConfig, HeartbeatDetector,
                                  TelemetryConfig)

N_NODES = 4
NODE_BUDGET_W = 4000.0          # power-constrained nodes (fig9 regime)
POLICY = policy_4p4d(500)
TTFT_SLO_S = 2.0
TPOT_SLO_S = 0.040
BASE_QPS = 8.0                  # steady arrivals; the surge rides on top
DEAD_NODE = 3


def n_requests(fast: bool) -> int:
    return 160 if fast else 480


def fault_schedule(fast: bool):
    """Faults pinned to the workload's expected span ``T``: the telemetry
    freeze opens early (controllers must hold), the controller crash
    opens at 0.40T and the surge + node death land INSIDE it — the
    headless data plane and the heartbeat detector carry the fleet until
    the restart at 0.60T re-levels and recovers."""
    T = n_requests(fast) / BASE_QPS
    return {
        "t_freeze": 0.15 * T, "freeze_dur": 0.20 * T,
        "t_crash": 0.40 * T, "crash_dur": 0.20 * T,
        "t_surge": 0.42 * T,
        "n_surge": 120 if fast else 240, "surge_qps": 40.0,
        "t_death": 0.45 * T,
        "t_rejoin": 0.75 * T,
    }


def baseline(fast: bool, seed: int):
    """Steady Poisson arrivals (drawn at build time — the run itself is
    deterministic), identical across arms."""
    from repro.core.simulator import Workload
    n = n_requests(fast)
    t = Workload.poisson_arrivals(n, BASE_QPS, np.random.default_rng(seed))
    return Workload([(float(t[i]), 4096, 256, TTFT_SLO_S, TPOT_SLO_S)
                     for i in range(n)], name="control_chaos_baseline")


def _run(arm: str, fast: bool, seed: int = 3):
    assert arm in ("oracle", "naive", "failsafe"), arm
    telemetry = (TelemetryConfig(act_on_stale=True) if arm == "naive"
                 else TelemetryConfig())
    admission = (None if arm == "naive"
                 else AdmissionConfig(slo_aware=True))
    cs = ClusterSimulator(
        get_config("llama31_8b"), POLICY, N_NODES,
        node_budget_w=NODE_BUDGET_W,
        ctrl_cfg=dyn_ctrl(gpu=False, ttft_slo=TTFT_SLO_S),
        cluster_cfg=ClusterConfig(allow_shift=True), seed=7,
        admission=admission, telemetry=telemetry)
    fm = FleetManager(cs, FleetConfig())
    det = None
    if arm == "failsafe":
        det = HeartbeatDetector(fm, HeartbeatConfig())
        det.start()
    ch = ChaosEngine(fm, ChaosConfig(seed=seed))
    f = fault_schedule(fast)
    # data-plane faults: identical in every arm
    ch.schedule_surge(f["t_surge"], f["n_surge"], qps=f["surge_qps"],
                      input_tokens=4096, output_tokens=256,
                      ttft_slo=TTFT_SLO_S, tpot_slo=TPOT_SLO_S)
    if arm == "failsafe":
        # physical death: recovery waits on the heartbeat detector
        ch.schedule_node_death(f["t_death"], DEAD_NODE)
    else:
        # oracle detection: the fleet knows the instant it happens
        ch.schedule_rack_failure(f["t_death"], [DEAD_NODE])
    fm.schedule_join(f["t_rejoin"], DEAD_NODE)
    # control-plane faults: only the non-oracle arms
    if arm != "oracle":
        ch.schedule_telemetry_freeze(f["t_freeze"], f["freeze_dur"])
        ch.schedule_controller_crash(f["t_crash"], f["crash_dur"])
    s = cs.run(baseline(fast, seed))
    # facility invariant over the whole run, headless windows included:
    # committed node budgets never exceed the nameplate facility budget
    for t, budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6, (arm, t, total)
    assert all(np.isfinite(r.energy_j) and r.energy_j >= 0
               for r in cs.records), "every record must carry finite joules"
    if arm != "oracle":
        kinds = [k for _, k, _ in cs.crash_trace]
        assert kinds == ["crash", "restart"], (arm, cs.crash_trace)
        assert cs.hold_trace, \
            f"{arm}: the freeze must trip the staleness bound"
    if arm == "failsafe":
        trans = [k for _, nid, k in det.trace if nid == DEAD_NODE]
        assert trans[:2] == ["suspected", "dead"], det.trace
        churn = [k for _, k, nid in fm.churn_trace if nid == DEAD_NODE]
        assert "dead_detected" in churn, fm.churn_trace
    return cs, fm, s


def fingerprint(cs):
    """Per-request record tuple set — the bit-identity gate."""
    return [(r.rid, r.arrival, r.prefill_done, r.finish, r.energy_j,
             r.shed_t) for r in cs.records]


def sweep(fast: bool, seed: int = 3):
    rows = []
    att = {}
    for arm in ("oracle", "naive", "failsafe"):
        cs, fm, s = _run(arm, fast, seed)
        att[arm] = s.slo_attainment
        rows.append({
            "arm": arm,
            "slo_attainment": s.slo_attainment,
            "goodput_rps": s.goodput_rps,
            "p90_ttft_s": s.p90_ttft, "p90_tpot_s": s.p90_tpot,
            "n_shed": s.n_shed, "shed_energy_j": s.shed_energy_j,
            "total_energy_j": s.total_energy_j,
            "energy_per_good_token_j": s.energy_per_good_token_j,
            "stale_holds": len(cs.hold_trace),
            "fenced_grants": len(cs.fence_trace),
            "crash": [(round(t, 2), k, e) for t, k, e in cs.crash_trace],
            "churn": [(round(t, 2), k, nid)
                      for t, k, nid in fm.churn_trace],
        })
        print(f"{arm:9s} att={s.slo_attainment*100:5.1f}%  "
              f"TTFT p90 {s.p90_ttft:5.2f}s  "
              f"goodput {s.goodput_rps:5.2f} req/s  "
              f"shed={s.n_shed} holds={len(cs.hold_trace)} "
              f"fenced={len(cs.fence_trace)}")
    gain = att["failsafe"] - att["naive"]
    print(f"\nfailsafe vs naive under the identical fault schedule: "
          f"{att['failsafe']*100:.1f}% vs {att['naive']*100:.1f}% "
          f"(+{gain*100:.1f}pp; oracle upper bound "
          f"{att['oracle']*100:.1f}%)")
    assert att["failsafe"] >= att["naive"], \
        "staleness holds + headless shedding + heartbeat detection must " \
        "not lose to controllers blindly acting on frozen state"
    # determinism gate: same arm, same seed, bit-identical records
    cs_a, _, _ = _run("failsafe", fast, seed)
    cs_b, _, _ = _run("failsafe", fast, seed)
    assert fingerprint(cs_a) == fingerprint(cs_b), \
        "control-plane chaos runs must be bit-identical per seed"
    print("rerun determinism: bit-identical per-request records  OK")
    return rows


def main(fast: bool = False, seed: int = 3):
    tm = Timer().start()
    rows = sweep(fast, seed)
    save_artifact("fig14_control_chaos", {"sweep": rows, "seed": seed},
                  timer=tm.stop())
    return rows


if __name__ == "__main__":
    main()
