"""Beyond-paper studies:

  (1) TPU-v5e projection — the same RAPID controller on an 8-chip v5e group
      (the hardware-adaptation target; power model from
      ``power_model.tpu_v5e_group``, chip constants from ``TPU_V5E``);
  (2) controller ablations — cooldown and queue-threshold sweeps
      (stability-vs-responsiveness trade-off the paper motivates
      qualitatively in Section 3.3);
  (3) rack-scale extrapolation — 16- and 32-GPU nodes (paper Section 7
      future work: "the underlying algorithms can be applied to rack-scale
      deployments").
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import Timer, save_artifact
from repro.configs import get_config
from repro.core.controller import ControllerConfig, StaticPolicy, policy_4p4d
from repro.core.costmodel import TPU_V5E
from repro.core.power_model import tpu_v5e_group
from repro.core.simulator import NodeSimulator, Workload


def dyn(**kw):
    return dataclasses.replace(ControllerConfig(), allow_power=True,
                               allow_gpu=True, **kw)


def tpu_projection(fast=False):
    """8-chip v5e group, 1240 W group budget (8 x 155 W provisioned of
    200 W TBP-equivalent envelope). Smaller model (chip HBM is 16 GB)."""
    cfg = get_config("qwen1.5-4b")
    n = 200 if fast else 400
    rows = []
    print("TPU-v5e group (8 chips, 1240 W budget), qwen1.5-4b:")
    for label, pol, ctrl in [
        ("4P4D-155W (uniform)", StaticPolicy(4, 4, 155, 155), None),
        ("4P-200W/4D-110W", StaticPolicy(4, 4, 200, 110), None),
        ("RAPID dyn", StaticPolicy(4, 4, 155, 155),
         dyn(decode_cap_max_w=160.0)),
    ]:
        wl = Workload.sonnet_phases(1.25, seed=5, n1=n, n2=n,
                                    tpot1=0.060, tpot2=0.040)
        sim = NodeSimulator(cfg, pol, node_budget_w=1240.0, gpu=TPU_V5E,
                            power=tpu_v5e_group(), ctrl_cfg=ctrl,
                            min_cap_w=110.0, max_cap_w=200.0)
        s = sim.run(wl)
        rows.append({"config": label, "slo": s.slo_attainment,
                     "qps_per_kw": s.qps_per_kw})
        print(f"  {label:24s} att={s.slo_attainment*100:5.1f}%  "
              f"QPS/kW {s.qps_per_kw:5.2f}")
    return rows


def cooldown_ablation(fast=False):
    """Paper Section 3.3: cooldown prevents oscillation; too long is sluggish."""
    cfg = get_config("llama3.1-8b")
    n = 150 if fast else 300
    rows = []
    print("\ncooldown ablation (GPU-move cooldown, DynGPU+DynPower, Sonnet):")
    for cd in (0.5, 1.5, 3.0, 6.0, 12.0):
        wl = Workload.sonnet_phases(6.5, seed=5, n1=n, n2=n)
        sim = NodeSimulator(cfg, policy_4p4d(600), ctrl_cfg=dyn(cooldown_s=cd))
        s = sim.run(wl)
        moves = len(sim.ctrl.trace)
        gpu_moves = sum(1 for _, k, _ in sim.ctrl.trace if k == "gpu")
        rows.append({"cooldown_s": cd, "slo": s.slo_attainment,
                     "moves": moves, "gpu_moves": gpu_moves})
        print(f"  cooldown {cd:5.1f}s  att={s.slo_attainment*100:5.1f}%  "
              f"moves={moves:3d} (gpu {gpu_moves})")
    return rows


def queue_threshold_ablation(fast=False):
    cfg = get_config("llama3.1-8b")
    n = 150 if fast else 300
    rows = []
    print("\nqueue-threshold ablation (early-warning trigger):")
    for q in (1, 4, 16, 64):
        wl = Workload.sonnet_phases(6.5, seed=5, n1=n, n2=n)
        sim = NodeSimulator(cfg, policy_4p4d(600),
                            ctrl_cfg=dyn(queue_threshold=q))
        s = sim.run(wl)
        rows.append({"threshold": q, "slo": s.slo_attainment})
        print(f"  |Q_P| > {q:3d}  att={s.slo_attainment*100:5.1f}%")
    return rows


def rack_scale(fast=False):
    """Scale node size at fixed per-GPU budget (600 W) and per-GPU rate."""
    cfg = get_config("llama3.1-8b")
    rows = []
    print("\nrack-scale extrapolation (same per-GPU load, 0.8 QPS/GPU):")
    for n_gpus in (8, 16, 32):
        half = n_gpus // 2
        n = (40 if fast else 75) * n_gpus
        wl = Workload.sonnet_phases(0.8125 * n_gpus, seed=5, n1=n, n2=n)
        pol = StaticPolicy(half, half, 600, 600)
        sim = NodeSimulator(cfg, pol, node_budget_w=600.0 * n_gpus,
                            ctrl_cfg=dyn())
        s = sim.run(wl)
        rows.append({"n_gpus": n_gpus, "slo": s.slo_attainment,
                     "goodput_rps": s.goodput_rps})
        print(f"  {n_gpus:2d} GPUs  att={s.slo_attainment*100:5.1f}%  "
              f"goodput {s.goodput_rps:6.2f} req/s "
              f"({s.goodput_rps/n_gpus:5.3f} /GPU)")
    return rows


def main(fast: bool = False):
    tm = Timer().start()
    out = {
        "tpu_projection": tpu_projection(fast),
        "cooldown": cooldown_ablation(fast),
        "queue_threshold": queue_threshold_ablation(fast),
        "rack_scale": rack_scale(fast),
    }
    save_artifact("beyond_ablations", out, timer=tm.stop())
    return out["cooldown"]


if __name__ == "__main__":
    main()
