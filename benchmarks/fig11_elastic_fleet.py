"""Elastic fleet under diurnal load and node churn (goes beyond the paper):
the same facility power cap, the same churn events — maintenance pulls a
node mid-ramp and an unplanned failure hits near the peak — handled two
ways:

  static    the fleet has no elasticity machinery: the pulled node's
            in-flight work is lost and re-enters from scratch through the
            router, its watts stay stranded while it is away, and it
            returns at its nameplate budget;
  elastic   FleetManager (core/fleet.py): a graceful leave drains the node
            — live decode batches migrate cross-node with their KV over the
            interconnect, queued work re-routes for free — and facility-
            level DISTRIBUTEUNIFORMPOWER re-levels watts across every
            membership change (survivors absorb the departed watts;
            a join shrinks them back first, source-before-sink).

The workload is diurnal: a trough, a 2.5x peak, a trough — sized so the
surviving nodes ride their capacity knee at the peak, which is exactly when
the failure hits. Elasticity pays twice: migration preserves prefill/decode
progress the static arm throws away (the re-prefill storm lands on top of
peak traffic), and redistribution lets survivors raise caps with the
departed watts right when they are short.

Per-request energy accounting rides along: every record carries the joules
actually burned for it (including work a failure wasted), and the summary's
``energy_per_good_token_j`` prices the churn-handling strategies in
J per SLO-good token.

Asserted here (fast mode too — this is a CI gate): the elastic arm beats
the static arm on SLO attainment under the identical facility cap and churn
schedule, every record's ``energy_j`` is finite and positive, and the
facility invariant holds over the recorded budget trace.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, dyn_ctrl, save_artifact
from repro.configs import get_config
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import policy_4p4d
from repro.core.fleet import FleetConfig, FleetManager
from repro.core.simulator import Workload

N_NODES = 3
NODE_BUDGET_W = 4000.0          # power-constrained nodes (fig9 regime)
POLICY = policy_4p4d(500)
TTFT_SLO_S = 2.0
TROUGH_QPS = 4.0                # whole-fleet arrival rates
PEAK_QPS = 10.0

def phase_sizes(fast: bool):
    return (40, 110, 40) if fast else (120, 330, 120)


def churn_schedule(fast: bool):
    """Churn pinned to the diurnal shape, not wall seconds: maintenance
    pulls node 2 mid-trough, returns it just after the peak arrives, and
    the unplanned failure kills node 1 a third of the way into the peak —
    when the fleet is closest to its capacity knee."""
    n1, n2, _ = phase_sizes(fast)
    trough = n1 / TROUGH_QPS            # expected phase durations
    peak = n2 / PEAK_QPS
    return (0.5 * trough,               # leave
            trough + 0.1 * peak,        # rejoin
            trough + 0.35 * peak)       # fail


def diurnal(fast: bool, seed: int) -> Workload:
    n1, n2, n3 = phase_sizes(fast)
    def mk(n: int, qps: float, s: int) -> Workload:
        return Workload.uniform(
            n, qps=qps, in_tokens=4096, out_tokens=256, seed=s,
            ttft_slo=TTFT_SLO_S, tpot_slo=0.040)
    return Workload.phased_mix(
        [mk(n1, TROUGH_QPS, seed), mk(n2, PEAK_QPS, seed + 1),
         mk(n3, TROUGH_QPS, seed + 2)], name="diurnal")


def _run(elastic: bool, fast: bool, seed: int = 4):
    cs = ClusterSimulator(get_config("llama31_8b"), POLICY, N_NODES,
                          node_budget_w=NODE_BUDGET_W,
                          ctrl_cfg=dyn_ctrl(gpu=False, ttft_slo=TTFT_SLO_S),
                          cluster_cfg=ClusterConfig(allow_shift=True),
                          seed=7)
    fm = FleetManager(cs, FleetConfig(elastic=elastic))
    t_leave, t_rejoin, t_fail = churn_schedule(fast)
    fm.schedule_leave(t_leave, 2)
    fm.schedule_join(t_rejoin, 2)
    fm.schedule_fail(t_fail, 1)
    s = cs.run(diurnal(fast, seed))
    for t, budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6, (t, budgets, total)
    assert all(np.isfinite(r.energy_j) and r.energy_j > 0
               for r in cs.records), "every record must carry spent joules"
    return cs, fm, s


def sweep(fast: bool):
    rows = []
    att = {}
    for name, elastic in (("static", False), ("elastic", True)):
        cs, fm, s = _run(elastic, fast)
        att[name] = s.slo_attainment
        rows.append({
            "arm": name,
            "slo_attainment": s.slo_attainment,
            "goodput_rps": s.goodput_rps,
            "p90_ttft_s": s.p90_ttft, "p90_tpot_s": s.p90_tpot,
            "avg_provisioned_w": s.avg_provisioned_w,
            "qps_per_kw": s.qps_per_kw,
            "total_energy_j": s.total_energy_j,
            "energy_per_good_token_j": s.energy_per_good_token_j,
            "migrations": len(fm.migration_trace),
            "requeues": len(fm.requeue_trace),
            "churn": [(round(t, 2), k, n) for t, k, n in fm.churn_trace],
            "final_budgets": [nd.pm.budget for nd in cs.nodes],
        })
        print(f"{name:8s} att={s.slo_attainment*100:5.1f}%  "
              f"TTFT p90 {s.p90_ttft:5.2f}s  "
              f"J/good-tok {s.energy_per_good_token_j:5.2f}  "
              f"avg {s.avg_provisioned_w/1e3:4.1f} kW  "
              f"migr={len(fm.migration_trace)} "
              f"requeue={len(fm.requeue_trace)}")
    gain = att["elastic"] - att["static"]
    print(f"\nelastic vs static under identical cap+churn: "
          f"{att['elastic']*100:.1f}% vs {att['static']*100:.1f}% "
          f"(+{gain*100:.1f}pp)")
    print("energy per SLO-good token:  " + "  ".join(
        f"{r['arm']}={r['energy_per_good_token_j']:.2f} J"
        for r in rows))
    assert att["elastic"] > att["static"], \
        "migration + power redistribution must beat the static node set " \
        "under the same facility cap and churn schedule"
    return rows


def main(fast: bool = False):
    tm = Timer().start()
    rows = sweep(fast)
    save_artifact("fig11_elastic_fleet", {"sweep": rows}, timer=tm.stop())
    return rows


if __name__ == "__main__":
    main()
