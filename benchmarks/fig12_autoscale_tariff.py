"""Predictive autoscaling on a price-varying diurnal tariff (fig12 —
beyond the paper; ROADMAP item 2, the fleet *decision* loop).

The same two-day diurnal workload — trough, a 5.5x peak, trough, twice —
runs against the same 4-node facility (2 serving, 2 dark standby) and the
same electricity-price / carbon-intensity traces, under three membership
policies:

  static      the fleet never touches the standby pool: 2 nodes ride the
              peak alone, far past their capacity knee;
  reactive    ``PredictiveAutoscaler(mode="reactive")``: demand is the
              *observed* trailing arrival rate, so every ramp is detected
              only after the queue already built — standby nodes power on
              mid-ramp and the migration/settle cost lands on top of peak
              traffic;
  predictive  ``mode="predictive"``: day 1 teaches the seasonal-naive
              forecaster the diurnal shape; on day 2 the ramp is forecast
              ``lead_s`` ahead and standby capacity is warm *before* load
              arrives. Troughs consolidate to the cheapest node set
              (worst trailing J/good-token drains first).

All three arms pay the identical tariff: each request's spent joules are
priced at the electricity price / carbon intensity in force when it
finished (``GoodputSummary.cost_per_good_token_usd`` /
``carbon_per_good_token_g``), and the router runs the price-weighted
``cost`` policy throughout.

Asserted here (fast mode too — this is a CI gate): predictive >= reactive
>= static on SLO attainment, predictive strictly cheaper than reactive
strictly cheaper than static in $/good-token, and the facility power
invariant holds across every autoscaler decision.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, dyn_ctrl, save_artifact
from repro.configs import get_config
from repro.core.autoscale import (AutoscaleConfig, PredictiveAutoscaler,
                                  SignalTrace)
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import policy_4p4d
from repro.core.fleet import FleetConfig, FleetManager
from repro.core.simulator import Workload

N_NODES = 4
STANDBY = (2, 3)                # dark pool; nodes 0-1 serve at t=0
NODE_BUDGET_W = 4000.0
POLICY = policy_4p4d(500)
TTFT_SLO_S = 2.0
TROUGH_QPS = 4.0                # whole-fleet arrival rates
PEAK_QPS = 22.0                 # past the 2-node knee, inside the fleet's
N_DAYS = 2                      # day 1 teaches the seasonal forecaster

OFFPEAK_PRICE = 0.10            # $/kWh
PEAK_PRICE = 0.35
OFFPEAK_CARBON = 300.0          # gCO2/kWh
PEAK_CARBON = 520.0


def phase_sizes(fast: bool):
    return (48, 288, 48) if fast else (144, 864, 144)


def day_phases(fast: bool):
    """(duration_s, qps) per phase of one diurnal day — durations are
    n/qps exactly because arrivals are uniform."""
    n1, n2, n3 = phase_sizes(fast)
    return ((n1 / TROUGH_QPS, TROUGH_QPS),
            (n2 / PEAK_QPS, PEAK_QPS),
            (n3 / TROUGH_QPS, TROUGH_QPS))


def day_len_s(fast: bool) -> float:
    return sum(d for d, _ in day_phases(fast))


def diurnal(fast: bool, seed: int) -> Workload:
    n1, n2, n3 = phase_sizes(fast)

    def mk(n: int, qps: float, s: int) -> Workload:
        return Workload.uniform(
            n, qps=qps, in_tokens=4096, out_tokens=256, seed=s,
            ttft_slo=TTFT_SLO_S, tpot_slo=0.040)

    phases = []
    for d in range(N_DAYS):
        phases += [mk(n1, TROUGH_QPS, seed + 3 * d),
                   mk(n2, PEAK_QPS, seed + 3 * d + 1),
                   mk(n3, TROUGH_QPS, seed + 3 * d + 2)]
    return Workload.phased_mix(phases, name="diurnal_tariff")


def tariff(fast: bool) -> tuple:
    """Price/carbon traces shaped to the day: peak tariff during the peak
    phase, off-peak otherwise, repeated for every simulated day."""
    (t1, _), (t2, _), _ = day_phases(fast)
    day = day_len_s(fast)
    times, prices, carbons = [0.0], [OFFPEAK_PRICE], [OFFPEAK_CARBON]
    for d in range(N_DAYS):
        t0 = d * day
        times += [t0 + t1, t0 + t1 + t2]
        prices += [PEAK_PRICE, OFFPEAK_PRICE]
        carbons += [PEAK_CARBON, OFFPEAK_CARBON]
    price = SignalTrace(times, prices, name="price", units="$/kWh")
    carbon = SignalTrace(times, carbons, name="carbon", units="gCO2/kWh")
    return price, carbon


def autoscale_cfg(mode: str, fast: bool) -> AutoscaleConfig:
    day = day_len_s(fast)
    return AutoscaleConfig(
        mode=mode, period_s=2.0, lead_s=10.0,
        target_util=0.75, scale_down_util=0.40,
        min_nodes=1, holdoff_s=8.0,
        bucket_s=2.0, window_s=min(20.0, day / 3.0),
        # only the predictive arm knows the diurnal period
        season_s=day if mode == "predictive" else None)


def _run(mode: str, fast: bool, seed: int = 4):
    cs = ClusterSimulator(get_config("llama31_8b"), POLICY, N_NODES,
                          node_budget_w=NODE_BUDGET_W,
                          ctrl_cfg=dyn_ctrl(gpu=False, ttft_slo=TTFT_SLO_S),
                          cluster_cfg=ClusterConfig(allow_shift=True),
                          seed=7, router_policy="cost")
    fm = FleetManager(cs, FleetConfig(elastic=True), standby=STANDBY)
    price, carbon = tariff(fast)
    asc = PredictiveAutoscaler(fm, autoscale_cfg(mode, fast),
                               price_trace=price, carbon_trace=carbon)
    asc.start()
    s = cs.run(diurnal(fast, seed))
    for t, budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6, (t, budgets, total)
    assert all(np.isfinite(r.energy_j) and r.energy_j > 0
               for r in cs.records), "every record must carry spent joules"
    return cs, fm, asc, s


def sweep(fast: bool):
    rows = []
    att, cost = {}, {}
    for mode in ("static", "reactive", "predictive"):
        cs, fm, asc, s = _run(mode, fast)
        att[mode] = s.slo_attainment
        cost[mode] = s.cost_per_good_token_usd
        rows.append({
            "arm": mode,
            "slo_attainment": s.slo_attainment,
            "goodput_rps": s.goodput_rps,
            "p90_ttft_s": s.p90_ttft, "p90_tpot_s": s.p90_tpot,
            "avg_provisioned_w": s.avg_provisioned_w,
            "qps_per_kw": s.qps_per_kw,
            "total_energy_j": s.total_energy_j,
            "energy_per_good_token_j": s.energy_per_good_token_j,
            "total_cost_usd": s.total_cost_usd,
            "cost_per_good_token_usd": s.cost_per_good_token_usd,
            "total_carbon_g": s.total_carbon_g,
            "carbon_per_good_token_g": s.carbon_per_good_token_g,
            "decisions": [(round(t, 2), k, n)
                          for t, k, n, *_ in asc.decision_trace],
            "migrations": len(fm.migration_trace),
            "churn": [(round(t, 2), k, n) for t, k, n in fm.churn_trace],
            "final_budgets": [nd.pm.budget for nd in cs.nodes],
        })
        print(f"{mode:11s} att={s.slo_attainment*100:5.1f}%  "
              f"TTFT p90 {s.p90_ttft:5.2f}s  "
              f"$/Mtok {s.cost_per_good_token_usd*1e6:6.2f}  "
              f"gCO2/Mtok {s.carbon_per_good_token_g*1e6:7.1f}  "
              f"joins+leaves={len(asc.decision_trace)}")
    print(f"\nSLO attainment:  predictive {att['predictive']*100:.1f}%  "
          f">= reactive {att['reactive']*100:.1f}%  "
          f">= static {att['static']*100:.1f}%")
    print(f"$/good-token:    predictive {cost['predictive']*1e6:.2f}  "
          f"< reactive {cost['reactive']*1e6:.2f}  "
          f"< static {cost['static']*1e6:.2f}  ($/Mtok)")
    assert att["predictive"] >= att["reactive"] >= att["static"], att
    assert cost["predictive"] < cost["reactive"] < cost["static"], \
        "powering capacity ahead of the ramp must buy strictly cheaper " \
        "good tokens on the price-varying diurnal trace"
    return rows


def main(fast: bool = False):
    tm = Timer().start()
    rows = sweep(fast)
    save_artifact("fig12_autoscale_tariff", {"sweep": rows}, timer=tm.stop())
    return rows


if __name__ == "__main__":
    main()
