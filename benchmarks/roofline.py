"""§Roofline: aggregate the dry-run artifacts into the per-(arch x shape)
three-term roofline table (single-pod 16x16 mesh), with the dominant term,
MODEL_FLOPS/HLO_FLOPs useful ratio, and an analytic HBM-traffic estimate
(XLA:CPU's 'bytes accessed' over-counts; see EXPERIMENTS.md §Roofline notes).
"""
from __future__ import annotations

import glob
import json
import os

from repro.analysis.hlo import TPU_V5E
from repro.configs import INPUT_SHAPES, get_config

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                          "dryrun")


def analytic_hbm_bytes(arch: str, shape_name: str, n_chips: int = 256) -> float:
    """Per-chip HBM traffic estimate: weights + optimizer + KV + activations."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    P = cfg.param_count()
    Pa = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    D = cfg.d_model
    if shape.kind == "train":
        # fwd read + bwd read + grad write + opt read/write (bf16 m,v)
        w = P * 2 * 3 + P * 2 * 4
        acts = tokens * D * 2 * 2 * cfg.n_layers // 8   # remat: layer inputs
        return (w + acts) / n_chips
    n_attn = sum(1 for k in cfg.layer_kinds() if k == "attn")
    kv_tok = 2 * n_attn * cfg.n_kv_heads * cfg.head_dim * 2
    if shape.kind == "prefill":
        return (P * 2 + tokens * kv_tok + tokens * D * 2 * 4) / n_chips
    # decode: weights (active) + full KV read + tiny write
    window = 8192 if shape.name == "long_500k" else shape.seq_len
    kv = shape.global_batch * min(shape.seq_len, window) * kv_tok
    return (Pa * 2 + kv) / n_chips


def load_records(mesh="16x16", tag=""):
    recs = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if r.get("tag", "") != tag:
            continue
        recs.append(r)
    return recs


def main(fast: bool = False):
    recs = load_records()
    if not recs:
        print("no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first")
        return []
    print(f"{'arch':>18s} {'shape':>12s} | {'compute':>9s} {'memory*':>9s} "
          f"{'coll':>9s} | dom       useful")
    rows = []
    for r in recs:
        rl = r["roofline"]
        mem_an = analytic_hbm_bytes(r["arch"], r["shape"]) / TPU_V5E.hbm_bw
        dom = max({"compute": rl["compute_s"], "memory": mem_an,
                   "collective": rl["collective_s"]}.items(),
                  key=lambda kv: kv[1])[0]
        rows.append({**{k: r[k] for k in ("arch", "shape", "kind", "n_chips")},
                     "compute_s": rl["compute_s"],
                     "memory_s_analytic": mem_an,
                     "memory_s_xla": rl["memory_s"],
                     "collective_s": rl["collective_s"],
                     "dominant": dom, "useful_ratio": rl["useful_ratio"],
                     "collectives": r["collectives"]})
        print(f"{r['arch']:>18s} {r['shape']:>12s} | {rl['compute_s']*1e3:8.2f}m "
              f"{mem_an*1e3:8.2f}m {rl['collective_s']*1e3:8.2f}m | "
              f"{dom:10s} {rl['useful_ratio']:6.2f}")
    with open(os.path.join(DRYRUN_DIR, "..", "roofline_table.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
