"""Walkthrough: an elastic fleet — node churn, cross-node request
migration, and per-request energy accounting under one facility cap.

Three MI300X nodes serve a diurnal stream (trough -> 2.5x peak -> trough).
Mid-ramp, maintenance pulls node 2: the FleetManager drains it — queued
prompts re-route for free, live decode batches migrate with their KV over
the node interconnect — then powers it off and re-levels its watts across
the survivors (facility-level DISTRIBUTEUNIFORMPOWER, raise-only side).
Just after the peak arrives the node rejoins: survivors shrink back toward
the uniform share first (source-before-sink, one level above the paper's
Algorithm 1) and the joiner powers on with the committed watts. Mid-peak,
node 1 fails abruptly: its in-flight work loses KV and re-enters through
the router from scratch while its watts move to the survivor.

Every request's record carries ``energy_j`` — the busy-draw joules
integrated along its actual prefill/decode path, wasted work included —
so the final summary prices the run in J per SLO-good token.

Run:  PYTHONPATH=src python examples/serve_fleet.py
"""
import dataclasses

from repro.configs import get_config
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import ControllerConfig, policy_4p4d
from repro.core.fleet import FleetConfig, FleetManager
from repro.core.simulator import Workload


def main():
    cfg = get_config("llama31_8b")
    ctrl = dataclasses.replace(ControllerConfig(ttft_slo=2.0),
                               allow_power=True, allow_gpu=False)
    cluster = ClusterSimulator(
        cfg, policy_4p4d(500), n_nodes=3,
        node_budget_w=4000.0,              # deliberately power-constrained
        ctrl_cfg=ctrl,
        cluster_cfg=ClusterConfig(allow_shift=True),
    )
    fleet = FleetManager(cluster, FleetConfig(elastic=True))
    print(f"facility budget: {cluster.facility_budget_w:.0f} W "
          f"({len(cluster.nodes)} nodes x 4000 W)")

    # diurnal arrivals: trough, peak, trough
    def mk(n, qps, s):
        return Workload.uniform(
            n, qps=qps, in_tokens=4096, out_tokens=256, seed=s,
            ttft_slo=2.0, tpot_slo=0.040)
    wl = Workload.phased_mix([mk(60, 4.0, 1), mk(160, 10.0, 2),
                              mk(60, 4.0, 3)], name="diurnal")

    fleet.schedule_leave(7.0, 2)      # maintenance window opens mid-trough
    fleet.schedule_join(17.0, 2)      # node returns as the peak builds
    fleet.schedule_fail(23.0, 1)      # unplanned failure at the peak

    summary = cluster.run(wl)

    print("\nchurn timeline:")
    for t, kind, nid in fleet.churn_trace:
        print(f"  t={t:6.2f}s  {kind:12s} node {nid}")
    print("\nbudget history (facility-level DISTRIBUTEUNIFORMPOWER):")
    moves = sorted((t, nd.node_id, w) for nd in cluster.nodes
                   for t, w in nd.pm.budget_history)
    for t, nid, w in moves:
        print(f"  t={t:6.2f}s  node {nid} -> {w:6.0f} W")
    print(f"\nmigrations: {len(fleet.migration_trace)} "
          f"(KV moved cross-node at an iteration boundary)")
    for t, rid, src, reason, ctx in fleet.migration_trace[:5]:
        print(f"  t={t:6.2f}s  req {rid:4d} left node {src} "
              f"({reason}, {ctx} ctx tokens)")
    if len(fleet.migration_trace) > 5:
        print(f"  ... {len(fleet.migration_trace) - 5} more")
    print(f"requeues after the failure: {len(fleet.requeue_trace)} "
          f"(KV lost, re-prefilled elsewhere)")

    print(f"\nfleet: {summary.row()}")
    print(f"  spent {summary.total_energy_j/1e3:.1f} kJ for "
          f"{summary.n_good} SLO-good requests -> "
          f"{summary.energy_per_good_token_j:.2f} J per good token")
    for nd in cluster.nodes:
        state = "up" if nd.pm.powered else "down"
        print(f"  node {nd.node_id}: {state:4s} budget {nd.pm.budget:6.0f} W "
          f"roles {''.join(g.role[0].upper() for g in nd.gpus)}")


if __name__ == "__main__":
    main()
