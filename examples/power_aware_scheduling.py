"""RAPID power experiments in miniature: static non-uniform power allocation
vs uniform disaggregation vs dynamic RAPID on the paper's two-phase Sonnet
workload (8-GPU MI300X node simulator, 4800 W budget).

Run:  PYTHONPATH=src python examples/power_aware_scheduling.py
"""
import dataclasses

from repro.configs import get_config
from repro.core.controller import (ControllerConfig, policy_4p4d,
                                   policy_nonuniform)
from repro.core.simulator import NodeSimulator, Workload


def main():
    cfg = get_config("llama3.1-8b")            # the paper's exemplar model
    base = ControllerConfig(tpot_slo=0.040)
    runs = [
        ("4P4D-600W (static uniform)", policy_4p4d(600), None),
        ("4P-750W/4D-450W (static non-uniform)",
         policy_nonuniform(750, 450), None),
        ("RAPID DynGPU+DynPower", policy_4p4d(600),
         dataclasses.replace(base, allow_power=True, allow_gpu=True)),
    ]
    for name, pol, ctrl in runs:
        wl = Workload.sonnet_phases(6.5, seed=5, n1=300, n2=300)
        sim = NodeSimulator(cfg, pol, node_budget_w=4800.0, ctrl_cfg=ctrl)
        s = sim.run(wl)
        print(f"{name:38s} SLO attainment {s.slo_attainment*100:5.1f}%  "
              f"({s.row()})")


if __name__ == "__main__":
    main()
