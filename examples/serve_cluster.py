"""Walkthrough: a heterogeneous 2-node cluster under one facility budget,
with the coordinator managing both node *budgets* and the cluster *role
mix*.

Node 0 is an MI300X node, node 1 an H100 node (~20% slower on an 8k
prefill). A prefill-heavy routed stream (8k-token prompts at 4 QPS per
node) stresses the cluster's static-role prefill capacity while node 0
also serves a pinned decode-heavy stream. Each node runs the RAPID
controller internally (per-GPU power shifting); the cluster coordinator
first tries to move *node budgets* (source-before-sink one level up) and —
once watts are exhausted, because both nodes are stressed — flips decode
GPUs to prefill on the least-stressed node (MoveGPU at cluster scale).
The power-aware router dispatches by effective role capacity, so the nodes
that gained prefill GPUs absorb proportionally more traffic.

Run:  PYTHONPATH=src python examples/serve_cluster.py
"""
import dataclasses

from repro.configs import get_config
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import ControllerConfig, policy_4p4d
from repro.core.costmodel import H100, MI300X
from repro.core.simulator import Workload


def main():
    cfg = get_config("llama31_8b")
    ctrl = dataclasses.replace(ControllerConfig(ttft_slo=2.0),
                               allow_power=True, allow_gpu=False)
    cluster = ClusterSimulator(
        cfg, policy_4p4d(500), n_nodes=2,
        node_budget_w=4000.0,              # deliberately power-constrained
        ctrl_cfg=ctrl,
        cluster_cfg=ClusterConfig(allow_shift=True, allow_gpu_move=True),
        gpu_specs=[MI300X, H100],          # heterogeneous hardware
    )
    print(f"facility budget: {cluster.facility_budget_w:.0f} W "
          f"({len(cluster.nodes)} nodes x 4000 W, "
          f"{' + '.join(nd.cost.gpu.name for nd in cluster.nodes)})")

    routed = Workload.uniform(200, qps=8.0, in_tokens=8192, out_tokens=128,
                              seed=5, ttft_slo=2.0, tpot_slo=0.040)
    decode_heavy = Workload.uniform(100, qps=2.0, in_tokens=500,
                                    out_tokens=500, seed=6, tpot_slo=0.030)
    summary = cluster.run(routed, pinned={0: decode_heavy})

    print(f"\ncluster: {summary.row()}")
    for nd, s in zip(cluster.nodes, cluster.node_summaries()):
        print(f"  node {nd.node_id} ({nd.cost.gpu.name}): {s.row()}")
        print(f"          budget {nd.pm.budget:.0f} W  "
              f"roles {''.join(g.role[0].upper() for g in nd.gpus)}  "
              f"caps {[round(c) for c in nd.pm.effective]}")
    print(f"\nbudget shifts ({len(cluster.shift_trace)}):")
    for t, src, dst, w in cluster.shift_trace:
        print(f"  t={t:7.2f}s  node{src} -> node{dst}  {w:.0f} W")
    print(f"role flips ({len(cluster.flip_trace)} requested, "
          f"{len(cluster.flip_done_trace)} completed):")
    for (t, node_id, direction), (td, nid, gid, role) in zip(
            cluster.flip_trace, cluster.flip_done_trace):
        print(f"  t={t:7.2f}s  node{node_id} {direction}  ->  "
              f"gpu{gid} is {role} at t={td:.2f}s")
    total = sum(nd.pm.budget for nd in cluster.nodes)
    print(f"\nfinal node budgets sum {total:.0f} W "
          f"<= facility {cluster.facility_budget_w:.0f} W "
          f"(invariant held on every tick and across every role-flip drain)")


if __name__ == "__main__":
    main()
