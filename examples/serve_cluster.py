"""Walkthrough: a 2-node cluster under one facility power budget.

Node 0 is fed prefill-heavy traffic (8k-token prompts), node 1 decode-heavy
(long generations). Each node runs the RAPID controller internally
(per-GPU power shifting); the cluster coordinator moves *node budgets*
between them with the same source-before-sink discipline one level up, and
the power-aware router would handle any un-pinned traffic.

Run:  PYTHONPATH=src python examples/serve_cluster.py
"""
import dataclasses

from repro.configs import get_config
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import ControllerConfig, policy_4p4d
from repro.core.simulator import Workload


def main():
    cfg = get_config("llama31_8b")
    ctrl = dataclasses.replace(ControllerConfig(ttft_slo=2.0),
                               allow_power=True, allow_gpu=False)
    cluster = ClusterSimulator(
        cfg, policy_4p4d(500), n_nodes=2,
        node_budget_w=4000.0,              # deliberately power-constrained
        ctrl_cfg=ctrl,
        cluster_cfg=ClusterConfig(allow_shift=True),
    )
    print(f"facility budget: {cluster.facility_budget_w:.0f} W "
          f"({len(cluster.nodes)} nodes x 4000 W)")

    prefill_heavy = Workload.uniform(60, qps=4.0, in_tokens=8192,
                                     out_tokens=128, seed=1,
                                     ttft_slo=2.0, tpot_slo=0.040)
    decode_heavy = Workload.uniform(60, qps=4.0, in_tokens=500,
                                    out_tokens=500, seed=2, tpot_slo=0.020)
    summary = cluster.run(pinned={0: prefill_heavy, 1: decode_heavy})

    print(f"\ncluster: {summary.row()}")
    for nd, s in zip(cluster.nodes, cluster.node_summaries()):
        print(f"  node {nd.node_id}: {s.row()}")
        print(f"          budget {nd.pm.budget:.0f} W  "
              f"caps {[round(c) for c in nd.pm.effective]}")
    print(f"\nbudget shifts ({len(cluster.shift_trace)}):")
    for t, src, dst, w in cluster.shift_trace:
        print(f"  t={t:7.2f}s  node{src} -> node{dst}  {w:.0f} W")
    total = sum(nd.pm.budget for nd in cluster.nodes)
    print(f"\nfinal node budgets sum {total:.0f} W "
          f"<= facility {cluster.facility_budget_w:.0f} W "
          f"(invariant held on every coordinator tick)")


if __name__ == "__main__":
    main()
