"""Walkthrough: predictive autoscaling on an electricity-price / carbon
tariff — the fleet's decision loop driving membership from the workload
and the grid.

Four MI300X nodes under one facility cap; two serve, two sit dark in the
standby pool (their watts concentrate on the serving pair). A two-day
diurnal stream runs against a time-of-use tariff whose peak price covers
the traffic peak. The ``PredictiveAutoscaler``:

  * feeds every admitted arrival to a trailing-window forecaster (EWMA
    level + trend; seasonal-naive once day 1 has been observed);
  * powers standby nodes on *ahead* of the day-2 ramp — the seasonal
    forecast sees it coming ``lead_s`` early, so prefill capacity is warm
    when the load lands;
  * at troughs drains the node with the worst trailing J/good-token
    (price-weighted marginal joules as tie-break) through the KV-aware
    migration path, and re-levels its watts across the survivors.

The price and carbon traces are first-class fleet inputs: the summary
prices every request's spent joules at the tariff in force when it
finished, so the run reports $/good-token and gCO2/good-token — the
objective the decision loop optimizes.

Run:  PYTHONPATH=src python examples/serve_autoscale.py
"""
import dataclasses

from repro.configs import get_config
from repro.core.autoscale import (AutoscaleConfig, PredictiveAutoscaler,
                                  SignalTrace)
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import ControllerConfig, policy_4p4d
from repro.core.fleet import FleetConfig, FleetManager
from repro.core.simulator import Workload

TROUGH_QPS, PEAK_QPS = 4.0, 22.0
DAY_S = 12.0 + 288 / PEAK_QPS + 12.0    # trough + peak + trough


def diurnal(seed: int) -> Workload:
    def mk(n, qps, s):
        return Workload.uniform(
            n, qps=qps, in_tokens=4096, out_tokens=256, seed=s,
            ttft_slo=2.0, tpot_slo=0.040)
    phases = []
    for d in range(2):                   # two days: day 1 teaches the season
        phases += [mk(48, TROUGH_QPS, seed + 3 * d),
                   mk(288, PEAK_QPS, seed + 3 * d + 1),
                   mk(48, TROUGH_QPS, seed + 3 * d + 2)]
    return Workload.phased_mix(phases, name="diurnal")


def main():
    cfg = get_config("llama31_8b")
    ctrl = dataclasses.replace(ControllerConfig(ttft_slo=2.0),
                               allow_power=True, allow_gpu=False)
    cluster = ClusterSimulator(
        cfg, policy_4p4d(500), n_nodes=4, node_budget_w=4000.0,
        ctrl_cfg=ctrl, cluster_cfg=ClusterConfig(allow_shift=True),
        router_policy="cost",            # price-weighted joules dispatch
    )
    fleet = FleetManager(cluster, FleetConfig(elastic=True), standby=(2, 3))

    # time-of-use tariff + grid carbon intensity, shaped to the day
    peak_start, peak_end = 12.0, 12.0 + 288 / PEAK_QPS
    knots, prices, carbons = [0.0], [0.10], [300.0]
    for d in range(2):
        knots += [d * DAY_S + peak_start, d * DAY_S + peak_end]
        prices += [0.35, 0.10]
        carbons += [520.0, 300.0]
    price = SignalTrace(knots, prices, name="price", units="$/kWh")
    carbon = SignalTrace(knots, carbons, name="carbon", units="gCO2/kWh")

    scaler = PredictiveAutoscaler(
        fleet,
        AutoscaleConfig(mode="predictive", period_s=2.0, lead_s=10.0,
                        window_s=14.0, holdoff_s=8.0, season_s=DAY_S),
        price_trace=price, carbon_trace=carbon)
    scaler.start()

    print(f"facility budget: {cluster.facility_budget_w:.0f} W "
          f"(2 serving + 2 standby nodes)")
    summary = cluster.run(diurnal(seed=4))

    print("\ndecision timeline (demand vs capacity, req/s, at the tariff):")
    for t, kind, nid, demand, cap, p in scaler.decision_trace:
        print(f"  t={t:6.1f}s  {kind:5s} node {nid}  "
              f"demand {demand:5.1f} vs cap {cap:5.1f}  @ ${p:.2f}/kWh")
    print(f"\nfleet: {summary.row()}")
    print(f"  {summary.n_good} SLO-good requests; "
          f"${summary.total_cost_usd:.4f} total electricity, "
          f"{summary.total_carbon_g:.0f} gCO2 -> "
          f"${summary.cost_per_good_token_usd * 1e6:.2f}/Mtok, "
          f"{summary.carbon_per_good_token_g * 1e6:.0f} gCO2/Mtok")
    for nd in cluster.nodes:
        state = "up" if nd.pm.powered else "down"
        print(f"  node {nd.node_id}: {state:4s} budget {nd.pm.budget:6.0f} W")


if __name__ == "__main__":
    main()
