"""Walkthrough: the control plane itself fails — frozen telemetry, a
coordinator crash with a node death inside it, and recovery by replay.

Four MI300X nodes serve a steady stream while the CONTROL plane (not the
data plane) has a bad day, scripted by the ``ChaosEngine`` on the shared
event loop so the whole incident replays bit-identically from its seed:

* a **telemetry freeze** pins every controller's view of node load and
  power to last-known-good; the coordinator and autoscaler notice the
  staleness bound tripping and HOLD instead of acting on fiction
  (``cluster.hold_trace`` records every refusal);
* a **controller crash** kills the coordinator and autoscaler for a
  window; nodes drop to fail-safe headless mode — last-committed local
  power caps guard-band the facility limit, and admission falls back to
  node-local SLO-aware shedding (``router.decide_local``);
* a **node death lands INSIDE the crash window**, and nobody gets an
  oracle notification: the ``HeartbeatDetector`` walks the node through
  alive -> suspected -> dead on heartbeat age alone, releasing the
  corpse's watts and requeueing its stranded work at DETECTION time;
* the **restart** bumps the controller epoch (in-flight budget grants
  issued by the dead incarnation are fenced, never committed), rebuilds
  the autoscaler's forecaster from its latest snapshot + journal replay,
  and re-levels the fleet's watts in one facility pass.

Run:  PYTHONPATH=src python examples/serve_control_chaos.py
"""
import numpy as np

from repro.configs import get_config
from repro.core.chaos import ChaosConfig, ChaosEngine
from repro.core.cluster import AdmissionConfig, ClusterConfig, ClusterSimulator
from repro.core.controller import ControllerConfig, policy_4p4d
from repro.core.fleet import FleetConfig, FleetManager
from repro.core.simulator import Workload
from repro.core.telemetry import (HeartbeatConfig, HeartbeatDetector,
                                  TelemetryConfig)


def main():
    cfg = get_config("llama31_8b")
    cluster = ClusterSimulator(
        cfg, policy_4p4d(500), n_nodes=4,
        node_budget_w=4000.0,              # deliberately power-constrained
        ctrl_cfg=ControllerConfig(ttft_slo=2.0, allow_power=True,
                                  allow_gpu=False),
        cluster_cfg=ClusterConfig(allow_shift=True), seed=7,
        admission=AdmissionConfig(slo_aware=True),
        telemetry=TelemetryConfig(),       # hold past max_staleness_s
    )
    fleet = FleetManager(cluster, FleetConfig())
    detector = HeartbeatDetector(fleet, HeartbeatConfig())
    detector.start()
    chaos = ChaosEngine(fleet, ChaosConfig(seed=7))
    print(f"facility budget: {cluster.facility_budget_w:.0f} W "
          f"({len(cluster.nodes)} nodes x 4000 W); heartbeat timeouts: "
          f"suspect {detector.cfg.suspect_after_s}s / "
          f"dead {detector.cfg.dead_after_s}s")

    chaos.schedule_telemetry_freeze(5.0, 6.0)
    chaos.schedule_controller_crash(14.0, 8.0)
    chaos.schedule_surge(15.0, n=60, qps=30.0, input_tokens=4096,
                         output_tokens=256, ttft_slo=2.0, tpot_slo=0.040)
    chaos.schedule_node_death(16.0, 3)     # inside the headless window
    fleet.schedule_join(28.0, 3)

    t = Workload.poisson_arrivals(240, 8.0, np.random.default_rng(1))
    wl = Workload([(float(ti), 4096, 256, 2.0, 0.040) for ti in t],
                  name="steady")
    summary = cluster.run(wl)

    print("\nchaos script (as scheduled):")
    for t0, kind, detail in chaos.trace:
        print(f"  t={t0:6.2f}s  {kind:18s} {detail}")
    print("\nstaleness holds during the freeze "
          f"({len(cluster.hold_trace)} total):")
    for t0, why, stale_s in cluster.hold_trace[:4]:
        print(f"  t={t0:6.2f}s  coordinator held ({why}, view "
              f"{stale_s:.2f}s old)")
    print("\ncontroller epoch ladder:")
    for t0, kind, epoch in cluster.crash_trace:
        print(f"  t={t0:6.2f}s  {kind:8s} epoch {epoch}")
    print(f"  fenced budget grants from dead epochs: "
          f"{len(cluster.fence_trace)}")
    print("\nheartbeat detector on node 3 (death was silent):")
    for t0, nid, kind in detector.trace:
        if nid == 3:
            print(f"  t={t0:6.2f}s  node {nid} -> {kind}")
    detected = [t0 for t0, kind, nid in fleet.churn_trace
                if kind == "dead_detected" and nid == 3]
    if detected:
        print(f"  stranded work requeued at detection (t={detected[0]:.2f}s,"
              f" {detected[0] - 16.0:.2f}s after the death itself)")
    shed = [r for r in cluster.records if r.shed_t is not None]
    print(f"\nheadless admission: shed {len(shed)} requests "
          f"({summary.shed_energy_j:.0f} J already burned on them)")

    print(f"\nfleet: {summary.row()}")
    for nd in cluster.nodes:
        state = "up" if nd.pm.powered else "down"
        print(f"  node {nd.node_id}: {state:4s} budget {nd.pm.budget:6.0f} W "
              f"roles {''.join(g.role[0].upper() for g in nd.gpus)}")


if __name__ == "__main__":
    main()
