"""Quickstart: build a small model from a config, train it a few steps on
synthetic data, then generate greedily with the prefill/decode API.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import LM
from repro.training.train_loop import train


def main():
    cfg = get_config("qwen1.5-4b").reduced()     # 2-layer smoke variant
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    params, hist = train(cfg, steps=30, batch_size=4, seq_len=64,
                         log_every=10, remat=False)
    print(f"loss: {hist[0]:.3f} -> {hist[-1]:.3f}")

    # greedy generation through the serving API
    lm = LM(cfg)
    prompt = jnp.arange(12)[None, :] % cfg.vocab_size
    cache = lm.init_cache(1, 48, dtype=jnp.float32)
    logits, cache = lm.prefill(params, {"tokens": prompt}, cache)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(10):
        logits, cache = lm.decode_step(params, jnp.asarray([toks[-1]]), cache)
        toks.append(int(jnp.argmax(logits[0])))
    print("generated token ids:", toks)


if __name__ == "__main__":
    main()
