"""Walkthrough: chaos day — a power emergency, a rack failure, and a
lossy migration link, absorbed by the degradation ladder.

Four MI300X nodes serve a steady stream when the facility's demand-
response program slashes the effective cap to 55% of nameplate for eight
seconds — and a traffic surge lands right inside the window. The
``ChaosEngine`` scripts all of it on the shared event loop, so the whole
bad day replays bit-identically from its seed:

* the **emergency** force-throttles every node source-before-sink
  (``PowerManager.emergency_shrink``), the autoscaler and coordinator
  hold, and the freed watts re-level back when the cap restores;
* the **surge** hits SLO-aware admission control: when projected TTFT
  violates the SLO fleet-wide, the router sheds the lowest-value
  requests instead of queueing everyone into violation — shed count and
  energy are reported separately, not laundered;
* the **rack failure** kills nodes 2 and 3 in one instant; the fleet
  re-levels the pooled watts in ONE facility pass, and the victims'
  requests re-enter through admission control;
* the **link fault** drops KV transfers during node 1's graceful drain;
  the migration engine retries with capped exponential backoff against
  each request's deadline before degrading to requeue-with-KV-loss.

Run:  PYTHONPATH=src python examples/serve_chaos.py
"""
import numpy as np

from repro.configs import get_config
from repro.core.chaos import ChaosConfig, ChaosEngine
from repro.core.cluster import AdmissionConfig, ClusterConfig, ClusterSimulator
from repro.core.controller import ControllerConfig, policy_4p4d
from repro.core.fleet import FleetConfig, FleetManager
from repro.core.simulator import Workload


def main():
    cfg = get_config("llama31_8b")
    cluster = ClusterSimulator(
        cfg, policy_4p4d(500), n_nodes=4,
        node_budget_w=4000.0,              # deliberately power-constrained
        ctrl_cfg=ControllerConfig(ttft_slo=2.0, allow_power=True,
                                  allow_gpu=False),
        cluster_cfg=ClusterConfig(allow_shift=True), seed=7,
        admission=AdmissionConfig(slo_aware=True),
    )
    fleet = FleetManager(cluster, FleetConfig())
    chaos = ChaosEngine(fleet, ChaosConfig(seed=7))
    print(f"facility budget: {cluster.facility_budget_w:.0f} W "
          f"({len(cluster.nodes)} nodes x 4000 W)")

    chaos.schedule_power_emergency(5.0, frac=0.55, duration_s=8.0)
    chaos.schedule_surge(6.0, n=40, qps=20.0, input_tokens=4096,
                         output_tokens=256, ttft_slo=2.0, tpot_slo=0.040)
    chaos.schedule_rack_failure(16.0, [2, 3])
    fleet.schedule_join(22.0, 2)
    fleet.schedule_join(22.5, 3)
    chaos.schedule_link_fault(26.0, node_id=1, duration_s=1.0, mode="fail")
    fleet.schedule_leave(26.0, 1)          # graceful drain over a bad link
    fleet.schedule_join(32.0, 1)

    t = Workload.poisson_arrivals(240, 8.0, np.random.default_rng(1))
    wl = Workload([(float(ti), 4096, 256, 2.0, 0.040) for ti in t],
                  name="steady")
    summary = cluster.run(wl)

    print("\nchaos script (as scheduled):")
    for t0, kind, detail in chaos.trace:
        print(f"  t={t0:6.2f}s  {kind:16s} {detail}")
    print("\nemergency ladder (begin -> enforced -> end):")
    for t0, kind, limit_w in fleet.emergency_trace:
        print(f"  t={t0:6.2f}s  {kind:9s} effective limit {limit_w:7.0f} W")
    print(f"\nmigration engine: {len(fleet.migration_trace)} arrivals, "
          f"{len(fleet.retry_trace)} retries, "
          f"{len(fleet.kv_loss_trace)} KV-loss fallbacks, "
          f"{len(fleet.stall_trace)} stalls ridden out")
    for t0, rid, src, why in fleet.kv_loss_trace[:4]:
        print(f"  t={t0:6.2f}s  req {rid:4d} lost KV leaving node {src} "
              f"({why}) -> re-prefill via admission")
    shed = [r for r in cluster.records if r.shed_t is not None]
    print(f"\nadmission control: shed {len(shed)} requests "
          f"({summary.shed_energy_j:.0f} J already burned on them)")

    print(f"\nfleet: {summary.row()}")
    for nd in cluster.nodes:
        state = "up" if nd.pm.powered else "down"
        print(f"  node {nd.node_id}: {state:4s} budget {nd.pm.budget:6.0f} W "
              f"roles {''.join(g.role[0].upper() for g in nd.gpus)}")


if __name__ == "__main__":
    main()
