"""End-to-end driver: serve a small model with batched requests through the
real-compute disaggregated engine — prefill workers fill actual KV caches,
the ring buffer hands tensors to decode workers (continuous batching with
per-slot positions), and the RAPID controller shifts power/roles live.

Run:  PYTHONPATH=src python examples/serve_disaggregated.py
"""
import numpy as np

from repro.configs import get_config
from repro.core.controller import ControllerConfig
from repro.serving.engine import DisaggEngine


def main():
    cfg = get_config("qwen1.5-4b").reduced()
    ctrl = ControllerConfig(ttft_slo=1.0, tpot_slo=0.04,
                            allow_power=True, allow_gpu=True)
    eng = DisaggEngine(cfg, n_prefill=2, n_decode=2, max_len=128,
                       decode_slots=6, ctrl_cfg=ctrl)
    rng = np.random.default_rng(0)
    for i in range(40):
        n_in = int(rng.integers(16, 64))
        n_out = int(rng.integers(8, 24))
        eng.submit(rng.integers(0, cfg.vocab_size, n_in).astype(np.int32),
                   n_out, 0.0)
    summary = eng.run()
    print(f"finished {summary.n_finished}/{summary.n_total}  {summary.row()}")
    print(f"controller moves: {len(eng.ctrl.trace)}")
    print(f"final caps: {[round(c) for c in eng.pm.effective]} "
          f"(budget {eng.pm.budget:.0f} W)")
    sample = eng.finished[0]
    print(f"sample request: {len(sample.tokens)} prompt tokens -> "
          f"{sample.generated}")


if __name__ == "__main__":
    main()
