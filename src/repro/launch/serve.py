"""Serving launcher: ``python -m repro.launch.serve --arch <id> [--smoke]``.

--smoke serves a reduced model through the real-compute disaggregated
engine (prefill worker -> ring buffer -> decode worker) with the RAPID
controller enabled. Without --smoke it builds + compiles the production
serve step for the requested shape (decode_32k by default).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs.base import INPUT_SHAPES, get_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prefill-workers", type=int, default=1)
    ap.add_argument("--decode-workers", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        from repro.core.controller import ControllerConfig
        from repro.serving.engine import DisaggEngine
        rcfg = cfg.reduced()
        eng = DisaggEngine(rcfg, n_prefill=args.prefill_workers,
                           n_decode=args.decode_workers, max_len=96,
                           decode_slots=4,
                           ctrl_cfg=ControllerConfig())
        rng = np.random.default_rng(0)
        for _ in range(args.requests):
            eng.submit(rng.integers(0, rcfg.vocab_size, 24).astype(np.int32),
                       12, 0.0)
        s = eng.run()
        print(f"[serve] {rcfg.name}: {s.n_finished}/{s.n_total} finished  "
              f"{s.row()}")
        return
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step
    mesh = make_production_mesh()
    shape = INPUT_SHAPES[args.shape]
    built = build_step(cfg, mesh, shape)
    with mesh:
        compiled = built.fn.lower(*built.args).compile()
    print(f"[serve] {cfg.name} {shape.name}: compiled for {mesh.shape}; "
          f"flops={compiled.cost_analysis().get('flops', 0):.3g}")


if __name__ == "__main__":
    main()
