"""PartitionSpec rules: parameters (by leaf path), batches, and KV/recurrent
caches (per family). These are the *baseline* sharding used by every
dry-run; perf iterations override pieces of them (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

_REPLICATED_LEAVES = {
    "ln", "ln1", "ln2", "ln_c", "w", "b", "q_norm", "k_norm", "b_in",
    "b_if", "lam", "final_norm", "enc_norm", "r", "w_r", "w_i",
    "conv_b", "b_r", "b_i", "pos", "count",
}


def param_spec(cfg: ModelConfig, path: str, ndim: int) -> P:
    parts = path.split("/")
    leaf = parts[-1]
    pre = (None,) * max(ndim - 2, 0)     # leading stack dims (group/layer)

    if leaf == "embed":
        return P("model", None)
    if leaf == "unembed":
        return P(None, "model")
    if leaf in _REPLICATED_LEAVES:
        return P(*(None,) * ndim)
    if leaf in ("wi", "wg", "wo") and ndim >= 4 and cfg.n_experts > 0:
        # stacked MoE expert weights (G, E, D, F) / (G, E, F, D): expert-parallel
        return P(*(None,) * (ndim - 3), "model", None, None)
    if leaf == "router":
        return P(*pre, None, "model")
    if leaf in ("wq", "wk", "wv", "w_up", "w_gate", "w_in", "wi", "wg"):
        return P(*pre, None, "model")
    if leaf in ("wo", "w_down", "w_out", "w_if"):
        return P(*pre, "model", None)
    if leaf in ("bq", "bk", "bv"):
        return P(*(None,) * (ndim - 1), "model")
    if leaf == "conv_w":
        return P(*pre, None, "model")
    return P(*(None,) * ndim)


def _path_str(path) -> str:
    out = []
    for p in path:
        out.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(out)


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop mesh-axis assignments that do not divide the dimension size
    (e.g. a 51866-token vocab over a 16-way model axis)."""
    out = []
    for d, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
        out.append(entry if shape[d] % prod == 0 else None)
    return P(*out)


def param_specs(cfg: ModelConfig, abstract_params, mesh=None):
    """PartitionSpec tree matching an (abstract) param tree."""
    def one(path, leaf):
        spec = param_spec(cfg, _path_str(path), leaf.ndim)
        return sanitize_spec(spec, leaf.shape, mesh) if mesh is not None else spec
    return jax.tree_util.tree_map_with_path(one, abstract_params)


def param_shardings(cfg, abstract_params, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, abstract_params, mesh))


def param_specs_fsdp(abstract_params, mesh, axes=("data", "model")):
    """ZeRO-3 storage sharding: every weight sharded over the flattened
    (data, model[, pod]) axes on its largest divisible dim. Compute-time
    re-gathering is done per layer via ``maybe_gather_params``."""
    if "pod" in mesh.axis_names:
        axes = ("pod",) + tuple(axes)
    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def one(leaf):
        dims = sorted(range(leaf.ndim), key=lambda d: -leaf.shape[d])
        for d in dims:
            if leaf.shape[d] % n == 0:
                spec = [None] * leaf.ndim
                spec[d] = tuple(axes)
                return P(*spec)
        return P(*(None,) * leaf.ndim)

    return jax.tree.map(one, abstract_params)


def param_shardings_fsdp(abstract_params, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs_fsdp(abstract_params, mesh))


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, abstract_batch, batch_axes: Tuple[str, ...]):
    b = batch_axes if batch_axes else None
    specs = {}
    for k, v in abstract_batch.items():
        specs[k] = P(b, *(None,) * (v.ndim - 1))
    return specs


# ---------------------------------------------------------------------------
# cache specs (mirror each family's init_cache structure)
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, abstract_cache, batch_axes: Tuple[str, ...],
                kv_seq_axis: Optional[str] = None):
    """kv_seq_axis: mesh axis to shard the KV sequence dim over (long-KV
    decode optimization); None = unsharded."""
    b = batch_axes if batch_axes else None

    def kv5(_):   # (G/L, B, S, K, H)
        return P(None, b, kv_seq_axis, None, None)

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return {
            "slots": tuple({"k": kv5(None), "v": kv5(None)}
                           for _ in abstract_cache["slots"]),
            "pos": P(),
        }
    if fam == "audio":
        return {"k": kv5(None), "v": kv5(None),
                "ck": P(None, b, None, None, None),
                "cv": P(None, b, None, None, None), "pos": P()}
    if fam == "ssm":
        # slots: mLSTM (C,n,m) or sLSTM (c,n,m,h); every leaf is (G,B,...)
        def leaf_spec(a):
            return P(None, b, *(None,) * (a.ndim - 2))
        return {
            "slots": jax.tree.map(leaf_spec, abstract_cache["slots"]),
            "pos": P(),
        }
    if fam == "hybrid":
        def slot_spec(slot, stacked: bool):
            n = 1 if stacked else 0
            if isinstance(slot, dict):      # attention: k/v (G?,B,S,K,H)
                return {"k": P(*(None,) * n, b, None, None, None),
                        "v": P(*(None,) * n, b, None, None, None)}
            # rec: (conv (G?,B,cw-1,W), h (G?,B,W))
            return (P(*(None,) * n, b, None, "model"),
                    P(*(None,) * n, b, "model"))
        return {
            "slots": tuple(slot_spec(s, True) for s in abstract_cache["slots"]),
            "rest": tuple(slot_spec(s, False) for s in abstract_cache["rest"]),
            "pos": P(),
        }
    raise ValueError(fam)


def cache_shardings(cfg, abstract_cache, mesh, batch_axes, kv_seq_axis=None):
    specs = cache_specs(cfg, abstract_cache, batch_axes, kv_seq_axis)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
