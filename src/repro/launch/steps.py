"""Build the jitted, sharding-annotated step functions for a (cfg, mesh,
input-shape) triple. Used by the dry-run, the launchers, and the benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.launch import shardspecs as SS
from repro.launch.mesh import batch_axes_for
from repro.models import LM, make_batch_specs
from repro.models.sharding import standard_rules, use_rules
from repro.training.optimizer import AdamWConfig, apply_updates, init_state

LONG_CONTEXT_WINDOW = 8192   # sliding-window used by full-attention archs
                             # for the long_500k shape (sub-quadratic decode)


@dataclasses.dataclass
class BuiltStep:
    fn: "jax.stages.Wrapped"
    args: tuple                # abstract arg values (ShapeDtypeStructs)
    mesh: object
    rules: dict
    kind: str


def effective_window(cfg: ModelConfig, shape: InputShape) -> Optional[int]:
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm", "audio"):
        return LONG_CONTEXT_WINDOW
    return cfg.window


def _rules_for(cfg: ModelConfig, mesh, overrides=None):
    rules = standard_rules("pod" in mesh.axis_names)
    if overrides:
        rules.update(overrides)
    return rules


def build_train_step(cfg: ModelConfig, mesh, shape: InputShape,
                     dtype=jnp.bfloat16, opt_cfg: Optional[AdamWConfig] = None,
                     rule_overrides=None, remat=True,
                     param_mode: str = "2d") -> BuiltStep:
    lm = LM(cfg)
    opt_cfg = opt_cfg or AdamWConfig(state_dtype=jnp.bfloat16)
    if param_mode == "fsdp":
        # ZeRO-3: no tensor parallelism; batch over every mesh axis;
        # per-layer weight all-gather inside the scan (fsdp_gather rule)
        fs = {"heads": None, "kv_heads": None, "d_ff": None, "experts": None,
              "vocab": None, "lru": None, "fsdp_gather": True,
              "batch": (("pod", "data", "model")
                        if "pod" in mesh.axis_names else ("data", "model"))}
        rule_overrides = {**fs, **(rule_overrides or {})}
    rules = _rules_for(cfg, mesh, rule_overrides)
    baxes = batch_axes_for(shape.global_batch, mesh)
    if param_mode == "fsdp":
        baxes = rules["batch"]
    window = effective_window(cfg, shape)

    abstract_params = lm.init_abstract(dtype)
    abstract_opt = jax.eval_shape(lambda p: init_state(opt_cfg, p),
                                  abstract_params)
    abstract_batch = make_batch_specs(cfg, shape.global_batch, shape.seq_len,
                                      dtype)
    if param_mode == "fsdp":
        p_shard = SS.param_shardings_fsdp(abstract_params, mesh)
    else:
        p_shard = SS.param_shardings(cfg, abstract_params, mesh)
    o_shard = {
        "m": p_shard, "v": p_shard,
        "count": NamedSharding(mesh, P()),
    }
    b_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           SS.batch_specs(cfg, abstract_batch, baxes))

    def train_step(params, opt_state, batch):
        with use_rules(rules, mesh):
            loss, grads = jax.value_and_grad(
                lambda p: lm.loss(p, batch, window=window, remat=remat))(params)
            params, opt_state, metrics = apply_updates(opt_cfg, params, grads,
                                                       opt_state)
            metrics["loss"] = loss
            return params, opt_state, metrics

    fn = jax.jit(train_step,
                 in_shardings=(p_shard, o_shard, b_shard),
                 out_shardings=(p_shard, o_shard,
                                NamedSharding(mesh, P())),
                 donate_argnums=(0, 1))
    return BuiltStep(fn, (abstract_params, abstract_opt, abstract_batch),
                     mesh, rules, "train")


def build_prefill_step(cfg: ModelConfig, mesh, shape: InputShape,
                       dtype=jnp.bfloat16, rule_overrides=None,
                       kv_seq_axis=None) -> BuiltStep:
    lm = LM(cfg)
    rules = _rules_for(cfg, mesh, rule_overrides)
    baxes = batch_axes_for(shape.global_batch, mesh)
    window = effective_window(cfg, shape)

    abstract_params = lm.init_abstract(dtype)
    abstract_batch = make_batch_specs(cfg, shape.global_batch, shape.seq_len,
                                      dtype, with_labels=False)
    abstract_cache = jax.eval_shape(
        lambda: lm.init_cache(shape.global_batch, shape.seq_len, dtype,
                              window=window))
    p_shard = SS.param_shardings(cfg, abstract_params, mesh)
    b_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           SS.batch_specs(cfg, abstract_batch, baxes))
    c_shard = SS.cache_shardings(cfg, abstract_cache, mesh, baxes, kv_seq_axis)

    def prefill_step(params, batch, cache):
        with use_rules(rules, mesh):
            logits, cache = lm.prefill(params, batch, cache, window=window)
            return jnp.argmax(logits, axis=-1), cache

    fn = jax.jit(prefill_step,
                 in_shardings=(p_shard, b_shard, c_shard),
                 out_shardings=(NamedSharding(mesh, P(baxes or None)), c_shard),
                 donate_argnums=(2,))
    return BuiltStep(fn, (abstract_params, abstract_batch, abstract_cache),
                     mesh, rules, "prefill")


def build_serve_step(cfg: ModelConfig, mesh, shape: InputShape,
                     dtype=jnp.bfloat16, rule_overrides=None,
                     kv_seq_axis=None) -> BuiltStep:
    """One decode step: new token given a KV cache of shape.seq_len."""
    lm = LM(cfg)
    if kv_seq_axis:
        rule_overrides = dict(rule_overrides or {}, kv_seq=kv_seq_axis)
    rules = _rules_for(cfg, mesh, rule_overrides)
    baxes = batch_axes_for(shape.global_batch, mesh)
    window = effective_window(cfg, shape)

    abstract_params = lm.init_abstract(dtype)
    abstract_cache = jax.eval_shape(
        lambda: lm.init_cache(shape.global_batch, shape.seq_len, dtype,
                              window=window))
    abstract_token = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    p_shard = SS.param_shardings(cfg, abstract_params, mesh)
    c_shard = SS.cache_shardings(cfg, abstract_cache, mesh, baxes, kv_seq_axis)
    t_shard = NamedSharding(mesh, P(baxes or None))

    def serve_step(params, token, cache):
        with use_rules(rules, mesh):
            logits, cache = lm.decode_step(params, token, cache, window=window)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    fn = jax.jit(serve_step,
                 in_shardings=(p_shard, t_shard, c_shard),
                 out_shardings=(t_shard, c_shard),
                 donate_argnums=(2,))
    return BuiltStep(fn, (abstract_params, abstract_token, abstract_cache),
                     mesh, rules, "decode")


def build_step(cfg: ModelConfig, mesh, shape: InputShape, **kw) -> BuiltStep:
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape, **kw)
    return build_serve_step(cfg, mesh, shape, **kw)
