"""Production meshes and the disaggregated mesh split.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module never touches jax device state.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes_for(global_batch: int, mesh) -> Tuple[str, ...]:
    """Largest prefix of the batch-capable mesh axes that divides the batch.

    bs=1 (long_500k) -> () i.e. replicated batch; bs=128 on (pod,data) -> both.
    """
    cand = [a for a in ("pod", "data") if a in mesh.axis_names]
    axes = []
    prod = 1
    for a in cand:
        n = mesh.shape[a]
        if global_batch % (prod * n) == 0:
            axes.append(a)
            prod *= n
    return tuple(axes)


def split_disagg_mesh(mesh, n_prefill: int):
    """Split the data axis of a mesh into prefill/decode sub-meshes.

    The TPU analogue of the paper's prefill/decode GPU pools: pool membership
    is a partition of the ``data`` axis; role reallocation re-partitions it
    (drain + re-form, charged 2-5 s by the controller).
    """
    devs = np.asarray(mesh.devices)            # (data, model) or (pod, data, model)
    axis = list(mesh.axis_names).index("data")
    assert 0 < n_prefill < devs.shape[axis]
    def take(sl):
        return np.take(devs, sl, axis=axis)
    pre = jax.sharding.Mesh(take(range(n_prefill)), mesh.axis_names)
    dec = jax.sharding.Mesh(take(range(n_prefill, devs.shape[axis])),
                            mesh.axis_names)
    return pre, dec
