# ruff: noqa: E402 -- the XLA device-count env var MUST be set before
# anything imports jax; import order here is load-bearing
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: lower + compile every (architecture x input-shape) on
the production meshes and record memory/cost/collective analysis.

The two lines above MUST stay first: jax locks the device count on first
initialization, and the production mesh needs 512 placeholder host devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-4b \
        --shape train_4k --mesh single --out artifacts/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback

import jax  # noqa: F401 -- locks the 512-device host platform now

from repro.analysis import hlo as H
from repro.analysis import hlo_graph as HG
from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step


def dryrun_one(arch: str, shape_name: str, multi_pod: bool, out_dir=None,
               rule_overrides=None, kv_seq_axis=None, tag="", verbose=True,
               param_mode=None):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.perf_counter()
    kw = {}
    if rule_overrides:
        kw["rule_overrides"] = rule_overrides
    if kv_seq_axis and shape.kind != "train":
        kw["kv_seq_axis"] = kv_seq_axis
    if param_mode and shape.kind == "train":
        kw["param_mode"] = param_mode
    built = build_step(cfg, mesh, shape, **kw)
    with mesh:
        lowered = built.fn.lower(*built.args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:          # CPU backend may not implement this
        mem_info = {"error": str(e)}
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    graph = HG.analyze(text)          # trip-corrected dot flops + collectives
    coll = dict(graph.coll_bytes)
    coll["_counts"] = graph.coll_counts
    model_flops = H.step_model_flops(cfg, shape)
    cost_corrected = dict(cost)
    cost_corrected["flops"] = max(float(cost.get("flops", 0) or 0),
                                  graph.dot_flops)
    rl = H.roofline_terms(cost_corrected, coll, n_chips, model_flops)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": built.kind, "n_chips": n_chips, "tag": tag,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": rl.flops,
        "flops_xla_single_trip": float(cost.get("flops", 0) or 0),
        "loops": graph.loops[:12],
        "bytes_per_device": rl.bytes_accessed,
        "collective_bytes_per_device": rl.coll_bytes,
        "collectives": {k: v for k, v in coll.items() if not k.startswith("_")},
        "collective_counts": coll["_counts"],
        "memory": mem_info,
        "roofline": {
            "compute_s": rl.compute_s, "memory_s": rl.memory_s,
            "collective_s": rl.collective_s, "dominant": rl.dominant,
            "model_flops": rl.model_flops, "useful_ratio": rl.useful_ratio,
        },
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fn = os.path.join(out_dir, f"{arch}_{shape_name}_{rec['mesh']}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        r = rec["roofline"]
        print(f"[dryrun] {arch:>18s} {shape_name:>12s} {rec['mesh']:>7s} "
              f"lower {t_lower:5.1f}s compile {t_compile:6.1f}s | "
              f"comp {r['compute_s']*1e3:9.3f}ms mem {r['memory_s']*1e3:9.3f}ms "
              f"coll {r['collective_s']*1e3:9.3f}ms -> {r['dominant']}"
              f" useful={r['useful_ratio']:.2f}", flush=True)
    return rec


def dryrun_disagg(arch: str, out_dir=None, n_prefill: int = 8, verbose=True):
    """RAPID's disaggregated deployment: the data axis of the single-pod mesh
    is split into a prefill pool and a decode pool (paper: GPU roles); the
    prefill step lowers+compiles on the prefill sub-mesh and the serve step
    on the decode sub-mesh. Proves a role re-partition always has a valid
    sharding on both sides (the controller's MoveGPU changes n_prefill)."""
    from repro.launch.mesh import split_disagg_mesh
    cfg = get_config(arch)
    mesh = make_production_mesh()
    pre_mesh, dec_mesh = split_disagg_mesh(mesh, n_prefill)
    t0 = time.perf_counter()
    pre = build_step(cfg, pre_mesh, INPUT_SHAPES["prefill_32k"])
    with pre_mesh:
        pre_c = pre.fn.lower(*pre.args).compile()
    dec = build_step(cfg, dec_mesh, INPUT_SHAPES["decode_32k"])
    with dec_mesh:
        dec_c = dec.fn.lower(*dec.args).compile()
    dt = time.perf_counter() - t0
    rec = {
        "arch": arch, "mode": "disagg",
        "prefill_mesh": str(dict(zip(pre_mesh.axis_names,
                                     pre_mesh.devices.shape))),
        "decode_mesh": str(dict(zip(dec_mesh.axis_names,
                                    dec_mesh.devices.shape))),
        "prefill_flops": float(pre_c.cost_analysis().get("flops", 0) or 0),
        "decode_flops": float(dec_c.cost_analysis().get("flops", 0) or 0),
        "compile_s": round(dt, 1),
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{arch}_disagg.json"), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        print(f"[dryrun-disagg] {arch:>18s} {n_prefill}P/"
              f"{mesh.shape['data']-n_prefill}D pools compiled in {dt:5.1f}s",
              flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--disagg", action="store_true",
                    help="lower the prefill/decode pool sub-mesh deployment")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    if args.disagg:
        fails = []
        for arch in archs:
            try:
                dryrun_disagg(arch, out_dir=args.out)
            except Exception:
                fails.append(arch)
                traceback.print_exc()
        if fails:
            raise SystemExit(f"disagg dry-run failures: {fails}")
        print("[dryrun] all disaggregated pool deployments compiled OK")
        return
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    dryrun_one(arch, shape, mp, out_dir=args.out)
                except Exception:
                    failures.append((arch, shape, mp))
                    print(f"[dryrun] FAILED {arch} {shape} multi_pod={mp}",
                          flush=True)
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print("[dryrun] all combinations lowered + compiled OK")


if __name__ == "__main__":
    main()
