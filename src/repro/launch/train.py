"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

--smoke runs the reduced config end-to-end on CPU. Without --smoke, builds
the production-mesh train step (requires a real TPU slice or the dry-run
device-count override) and runs ``--steps`` steps from the synthetic
pipeline.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, get_config
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        params, hist = train(cfg, steps=args.steps, batch_size=args.batch,
                             seq_len=args.seq, ckpt_path=args.ckpt)
        print(f"[train] {cfg.name}: loss {hist[0]:.3f} -> {hist[-1]:.3f}")
        return
    # production path: mesh + sharded step (same builder as the dry-run)
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_train_step
    from repro.training.data import TokenStream
    from repro.training.optimizer import AdamWConfig, init_state

    mesh = make_production_mesh()
    shape = INPUT_SHAPES["train_4k"]
    built = build_train_step(cfg, mesh, shape)
    lm_data = TokenStream(cfg)
    with mesh:
        from repro.models import LM
        lm = LM(cfg)
        params = lm.init(jax.random.key(0), dtype=jnp.bfloat16)
        opt = init_state(AdamWConfig(state_dtype=jnp.bfloat16), params)
        for step in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in
                     lm_data.batch(shape.global_batch, shape.seq_len).items()}
            params, opt, metrics = built.fn(params, opt, batch)
            print(f"step {step} loss {float(metrics['loss']):.4f}", flush=True)


if __name__ == "__main__":
    main()
