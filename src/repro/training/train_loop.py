"""Training loop: jitted train_step + a small driver usable on CPU (smoke /
examples) and under a production mesh (launch/train.py)."""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import LM
from repro.training import checkpoint as ckpt
from repro.training.data import TokenStream
from repro.training.optimizer import AdamWConfig, apply_updates, init_state


def make_train_step(lm: LM, opt_cfg: AdamWConfig, *, window=None, remat=True):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm.loss(p, batch, window=window, remat=remat))(params)
        params, opt_state, metrics = apply_updates(opt_cfg, params, grads,
                                                   opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics
    return train_step


def train(cfg: ModelConfig, *, steps: int = 50, batch_size: int = 8,
          seq_len: int = 128, seed: int = 0, param_dtype=jnp.float32,
          opt_cfg: Optional[AdamWConfig] = None, ckpt_path: Optional[str] = None,
          log_every: int = 10, remat=True):
    """End-to-end single-host training driver (used by examples + tests)."""
    lm = LM(cfg)
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps, warmup_steps=max(steps // 10, 1))
    params = lm.init(jax.random.key(seed), dtype=param_dtype)
    opt_state = init_state(opt_cfg, params)
    data = TokenStream(cfg, seed=seed)
    step_fn = jax.jit(make_train_step(lm, opt_cfg, remat=remat),
                      donate_argnums=(0, 1))
    history = []
    t0 = time.perf_counter()
    for step in range(steps):
        batch = {k: jnp.asarray(v) for k, v in
                 data.batch(batch_size, seq_len).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        history.append(loss)
        if log_every and (step % log_every == 0 or step == steps - 1):
            dt = time.perf_counter() - t0
            print(f"step {step:5d} loss {loss:7.4f} "
                  f"gnorm {float(metrics['grad_norm']):6.3f} "
                  f"({dt:6.1f}s)", flush=True)
    if ckpt_path:
        ckpt.save(ckpt_path, {"params": params}, step=steps)
    return params, history
