"""Synthetic data pipeline: deterministic, seekable token streams.

Produces next-token-prediction batches (tokens, labels) with document
boundaries (EOS-separated variable-length docs) so the loss mask and packing
logic are exercised like a real pipeline. Whisper batches additionally get
random frame embeddings from the stubbed audio frontend.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig


class TokenStream:
    """Deterministic infinite stream of EOS-packed synthetic documents."""

    def __init__(self, cfg: ModelConfig, seed: int = 0,
                 mean_doc_len: int = 512):
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        self.mean_doc_len = mean_doc_len
        self.eos = cfg.vocab_size - 1
        self._buf = np.empty((0,), np.int32)

    def _refill(self, need: int):
        docs = []
        total = self._buf.size
        while total < need:
            n = max(2, int(self.rng.exponential(self.mean_doc_len)))
            doc = self.rng.integers(0, self.cfg.vocab_size - 1, n).astype(np.int32)
            doc[-1] = self.eos
            docs.append(doc)
            total += n
        if docs:
            self._buf = np.concatenate([self._buf] + docs)

    def batch(self, batch_size: int, seq_len: int):
        need = batch_size * (seq_len + 1)
        self._refill(need)
        flat = self._buf[:need]
        self._buf = self._buf[need:]
        arr = flat.reshape(batch_size, seq_len + 1)
        tokens = arr[:, :-1].copy()
        labels = arr[:, 1:].copy()
        out = {"tokens": tokens, "labels": labels}
        if self.cfg.is_encoder_decoder:
            out["enc_feats"] = (self.rng.standard_normal(
                (batch_size, self.cfg.encoder_seq, self.cfg.d_model))
                .astype(np.float32) * 0.02)
        return out

    def __iter__(self):
        return self

    def batches(self, batch_size: int, seq_len: int):
        while True:
            yield self.batch(batch_size, seq_len)
