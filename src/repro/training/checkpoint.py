"""Flat-npz checkpointing for arbitrary param/optimizer pytrees."""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves_with_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any, step: int = 0):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    flat["__step__"] = np.asarray(step)
    np.savez(path, **flat)


def restore(path: str, like: Any):
    """Restore into the structure of ``like`` (a template pytree)."""
    if not path.endswith(".npz"):
        path += ".npz"
    data = np.load(path)
    step = int(data["__step__"])
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_elems, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_elems)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
