"""AdamW with dtype-configurable state (bf16 moments for the 400B-class
archs so the dry-run memory analysis reflects a deployable optimizer).
Implemented directly (no optax dependency)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: Any = jnp.float32       # jnp.bfloat16 for big models
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def lr_schedule(cfg: AdamWConfig) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        frac = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        cosine = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return cfg.lr * warm * (0.1 + 0.9 * cosine)
    return lr


def init_state(cfg: AdamWConfig, params):
    def zeros(p):
        return jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg)(count)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
        v_new = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g)
        step = (m_new / c1) / (jnp.sqrt(v_new / c2) + cfg.eps)
        if p.ndim >= 2:                                  # no decay on norms/bias
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return (p_new.astype(p.dtype), m_new.astype(cfg.state_dtype),
                v_new.astype(cfg.state_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
