"""Decoder-only transformer family: dense, MoE (optionally interleaved), VLM.

Layers are stacked into ``groups`` of ``moe_every`` slots and iterated with
``jax.lax.scan`` so compile time/HLO size is O(1) in depth (126-layer Llama-3
405B compiles as fast as a 2-layer smoke model). Each slot is one residual
block: pre-norm attention + pre-norm FFN (dense or MoE).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.moe_layer import init_moe, moe_forward
from repro.models.sharding import constrain, maybe_gather_params


def _slot_kinds(cfg):
    return cfg.ffn_kinds()[: cfg.moe_every]


def _n_groups(cfg):
    assert cfg.n_layers % max(cfg.moe_every, 1) == 0, (
        f"{cfg.name}: n_layers={cfg.n_layers} must divide moe_every={cfg.moe_every}")
    return cfg.n_layers // cfg.moe_every


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_slot(key, cfg, ffn_kind, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.init_norm(ks[0], cfg.d_model, cfg.norm, dtype),
        "ln2": L.init_norm(ks[1], cfg.d_model, cfg.norm, dtype),
        "attn": L.init_attention(ks[2], cfg, dtype),
    }
    if ffn_kind == "moe":
        p["ffn"] = init_moe(ks[3], cfg, dtype)
    else:
        p["ffn"] = L.init_mlp(ks[3], cfg, dtype, d_ff=cfg.d_ff_dense or cfg.d_ff)
    return p


def init_params(cfg, key, dtype=jnp.bfloat16):
    G = _n_groups(cfg)
    kinds = _slot_kinds(cfg)
    ks = jax.random.split(key, 3 + len(kinds))
    slots = []
    for i, kind in enumerate(kinds):
        layer_keys = jax.random.split(ks[3 + i], G)
        slot = jax.vmap(lambda k: _init_slot(k, cfg, kind, dtype))(layer_keys)
        slots.append(slot)
    return {
        "embed": L.embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
        "unembed": L.dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dtype),
        "final_norm": L.init_norm(ks[2], cfg.d_model, cfg.norm, dtype),
        "slots": tuple(slots),
    }


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
               window: Optional[int] = None):
    G = _n_groups(cfg)
    Sc = min(max_len, window) if window else max_len
    def kv():
        return jnp.zeros((G, batch, Sc, cfg.n_kv_heads, cfg.head_dim), dtype)
    return {
        "slots": tuple({"k": kv(), "v": kv()} for _ in _slot_kinds(cfg)),
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# block body (one group of slots)
# ---------------------------------------------------------------------------

def _ffn_apply(slot_p, x, cfg, ffn_kind, mode):
    if ffn_kind == "moe":
        out, aux = moe_forward(slot_p["ffn"], x, cfg,
                               dropless=(mode == "decode"))
        return out, aux
    return L.mlp_forward(slot_p["ffn"], x, cfg), jnp.zeros((), jnp.float32)


def _group_body(cfg, mode: str, window):
    kinds = _slot_kinds(cfg)

    def body(carry, xs):
        if mode == "train":
            x, aux = carry
            slot_params = xs
            new_caches = None
        else:
            x, aux, pos = carry
            slot_params, caches = xs
            new_caches = []
        for i, ffn_kind in enumerate(kinds):
            p = maybe_gather_params(slot_params[i])
            h = L.apply_norm(x, p["ln1"], cfg.norm, cfg.norm_eps)
            if mode == "train":
                a = L.attn_forward(p["attn"], h, cfg, window=window)
            elif mode == "prefill":
                a, kc, vc = L.attn_prefill(p["attn"], h, cfg, caches[i]["k"],
                                           caches[i]["v"], window=window)
                new_caches.append({"k": kc, "v": vc})
            else:  # decode
                a, kc, vc = L.attn_decode(p["attn"], h, cfg, caches[i]["k"],
                                          caches[i]["v"], pos, window=window)
                new_caches.append({"k": kc, "v": vc})
            x = x + a
            x = constrain(x, "batch", "seq", "d_model")
            h = L.apply_norm(x, p["ln2"], cfg.norm, cfg.norm_eps)
            f, aux_i = _ffn_apply(p, h, cfg, ffn_kind, mode)
            x = x + f
            x = constrain(x, "batch", "seq", "d_model")
            aux = aux + aux_i
        if mode == "train":
            return (x, aux), None
        return (x, aux, pos), tuple(new_caches)

    return body


def _run_stack(params, x, cfg, mode, cache=None, window=None, remat=False):
    body = _group_body(cfg, mode, window)
    if mode == "train":
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["slots"])
        return x, aux, None
    pos = cache["pos"]
    if remat and mode == "prefill":
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux, _), new_slots = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32), pos),
        (params["slots"], cache["slots"]))
    return x, aux, new_slots


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def _embed(params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    return constrain(x, "batch", None, "d_model")


def _logits(params, x, cfg):
    x = L.apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    logits = x @ params["unembed"]
    return constrain(logits, "batch", None, "vocab")


def forward_train(params, cfg, batch, *, window=None, remat=True):
    """Full-sequence forward. Returns (logits (B,S,V), aux_loss)."""
    x = _embed(params, batch["tokens"])
    x, aux, _ = _run_stack(params, x, cfg, "train", window=window, remat=remat)
    return _logits(params, x, cfg), aux


def prefill(params, cfg, batch, cache, *, window=None):
    """Process the prompt, fill the cache. Returns (last-token logits, cache)."""
    tokens = batch["tokens"]
    x = _embed(params, tokens)
    x, _, new_slots = _run_stack(params, x, cfg, "prefill", cache=cache,
                                 window=window)
    last = _logits(params, x[:, -1:, :], cfg)[:, 0]
    return last, {"slots": new_slots, "pos": jnp.asarray(tokens.shape[1], jnp.int32)}


def decode_step(params, cfg, token, cache, *, window=None):
    """One decode step. token: (B,) or (B,1). Returns (logits (B,V), cache)."""
    if token.ndim == 1:
        token = token[:, None]
    x = _embed(params, token)
    x, _, new_slots = _run_stack(params, x, cfg, "decode", cache=cache,
                                 window=window)
    logits = _logits(params, x, cfg)[:, 0]
    return logits, {"slots": new_slots, "pos": cache["pos"] + 1}
