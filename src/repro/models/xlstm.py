"""xLSTM family: mLSTM (matrix-memory, chunkwise-parallel) + sLSTM (scalar-
memory, sequential) blocks. [arXiv:2405.04517]

Layout: every ``slstm_every``-th layer is sLSTM, the rest mLSTM (7:1 in the
assigned 350M config). Layers are stacked into groups of ``slstm_every`` and
scanned, like the transformer family.

The mLSTM uses the stabilized chunkwise formulation (intra-chunk quadratic +
inter-chunk recurrent carry) for train/prefill, and the exact single-step
recurrence for decode, so decode is O(d^2) per token with *no* KV growth —
this is what makes the xLSTM "KV cache" a fixed-size state that RAPID's
disaggregated handoff transfers in one small message.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.sharding import constrain

CHUNK = 256
_NEG = -1e30


def _period(cfg):
    return cfg.slstm_every if cfg.slstm_every else cfg.n_layers


def _slot_kinds(cfg):
    return cfg.layer_kinds()[: _period(cfg)]


def _n_groups(cfg):
    p = _period(cfg)
    assert cfg.n_layers % p == 0
    return cfg.n_layers // p


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def _init_mlstm(key, cfg, dtype):
    D = cfg.d_model
    inner = 2 * D
    ks = jax.random.split(key, 8)
    return {
        "ln": L.init_norm(ks[0], D, cfg.norm, dtype),
        "w_up": L.dense_init(ks[1], (D, inner), dtype),
        "w_gate": L.dense_init(ks[2], (D, inner), dtype),
        "wq": L.dense_init(ks[3], (inner, inner), dtype),
        "wk": L.dense_init(ks[4], (inner, inner), dtype),
        "wv": L.dense_init(ks[5], (inner, inner), dtype),
        "w_if": L.dense_init(ks[6], (inner, 2 * cfg.n_heads), dtype, scale=0.02),
        "b_if": jnp.concatenate([jnp.zeros((cfg.n_heads,), dtype),
                                 jnp.full((cfg.n_heads,), 3.0, dtype)]),
        "w_down": L.dense_init(ks[7], (inner, D), dtype,
                               scale=1.0 / math.sqrt(inner)),
    }


def _mlstm_qkvif(p, x, cfg):
    """x: (B, S, D) -> q,k,v (B,S,nh,hd) fp32; log_i, log_f (B,S,nh) fp32."""
    B, S, _ = x.shape
    nh = cfg.n_heads
    up = x @ p["w_up"]
    inner = up.shape[-1]
    hd = inner // nh
    q = (up @ p["wq"]).reshape(B, S, nh, hd).astype(jnp.float32)
    k = (up @ p["wk"]).reshape(B, S, nh, hd).astype(jnp.float32) / math.sqrt(hd)
    v = (up @ p["wv"]).reshape(B, S, nh, hd).astype(jnp.float32)
    gif = (up @ p["w_if"] + p["b_if"]).astype(jnp.float32)
    log_i, f_raw = gif[..., :nh], gif[..., nh:]
    log_f = jax.nn.log_sigmoid(f_raw)
    gate = jax.nn.silu(x @ p["w_gate"])
    return q, k, v, log_i, log_f, gate


def _mlstm_chunk_scan(q, k, v, log_i, log_f, state):
    """Chunkwise-parallel stabilized mLSTM. Shapes: q,k,v (B,S,nh,hd);
    gates (B,S,nh). state = (C (B,nh,hd,hd), n (B,nh,hd), m (B,nh)).
    Returns (h (B,S,nh,hd), new_state)."""
    B, S, nh, hd = q.shape
    nc = -(-S // CHUNK)
    pad = nc * CHUNK - S
    if pad:
        def padfn(a, fill=0.0):
            return jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
                           constant_values=fill)
        q, k, v = padfn(q), padfn(k), padfn(v)
        log_i = padfn(log_i, _NEG)   # padded steps inject nothing
        log_f = padfn(log_f, 0.0)    # ... and do not decay the state
    def ch(a):
        return a.reshape(B, nc, CHUNK, *a.shape[2:]).swapaxes(0, 1)
    qc, kc, vc, lic, lfc = map(ch, (q, k, v, log_i, log_f))  # (nc,B,C,...)

    def chunk_body(carry, xs):
        C, n, m = carry                       # (B,nh,hd,hd),(B,nh,hd),(B,nh)
        qq, kk, vv, li, lf = xs               # (B,C,nh,hd) / (B,C,nh)
        F = jnp.cumsum(lf, axis=1)            # (B,C,nh) inclusive cumsum
        # D[t,s] = F_t - F_s + li_s for s <= t
        Dm = (F[:, :, None, :] - F[:, None, :, :] + li[:, None, :, :])
        tri = jnp.tril(jnp.ones((CHUNK, CHUNK), bool))
        Dm = jnp.where(tri[None, :, :, None], Dm, _NEG)   # (B,t,s,nh)
        m_intra = jnp.max(Dm, axis=2)                     # (B,C,nh)
        m_inter = m[:, None, :] + F                       # (B,C,nh)
        m_row = jnp.maximum(m_intra, m_inter)             # (B,C,nh)
        scores = jnp.einsum("bthd,bshd->btsh", qq, kk)    # (B,t,s,nh)
        w = scores * jnp.exp(Dm - m_row[:, :, None, :])
        intra = jnp.einsum("btsh,bshd->bthd", w, vv)
        inter = jnp.exp(m_inter - m_row)[..., None] * \
            jnp.einsum("bthd,bhde->bthe", qq, C)
        h_num = intra + inter
        qn = jnp.einsum("bthd,bhd->bth", qq, n)
        denom = jnp.abs(jnp.einsum("btsh->bth", w) +
                        jnp.exp(m_inter - m_row) * qn)
        denom = jnp.maximum(denom, jnp.exp(-m_row))
        h = h_num / denom[..., None]
        # chunk-end state update
        FL = F[:, -1:, :]                                  # (B,1,nh)
        log_w = FL - F + li                                # (B,C,nh)
        m_next = jnp.maximum(m + FL[:, 0], jnp.max(log_w, axis=1))
        scale_old = jnp.exp(m + FL[:, 0] - m_next)         # (B,nh)
        w_s = jnp.exp(log_w - m_next[:, None, :])          # (B,C,nh)
        C_new = C * scale_old[..., None, None] + \
            jnp.einsum("bsh,bshd,bshe->bhde", w_s, kk, vv)
        n_new = n * scale_old[..., None] + jnp.einsum("bsh,bshd->bhd", w_s, kk)
        return (C_new, n_new, m_next), h

    (C, n, m), hs = jax.lax.scan(chunk_body, state, (qc, kc, vc, lic, lfc))
    h = hs.swapaxes(0, 1).reshape(B, nc * CHUNK, nh, hd)
    if pad:
        h = h[:, :S]
    return h, (C, n, m)


def _mlstm_decode(q, k, v, log_i, log_f, state):
    """Single-step mLSTM. q,k,v: (B,nh,hd); gates (B,nh)."""
    C, n, m = state
    m_new = jnp.maximum(log_f + m, log_i)
    f_s = jnp.exp(log_f + m - m_new)
    i_s = jnp.exp(log_i - m_new)
    C = C * f_s[..., None, None] + i_s[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    n = n * f_s[..., None] + i_s[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)),
                        jnp.exp(-m_new))
    h = num / denom[..., None]
    return h, (C, n, m_new)


def _mlstm_block(p, x, cfg, state, mode):
    B, S, D = x.shape
    h_in = L.apply_norm(x, p["ln"], cfg.norm, cfg.norm_eps)
    q, k, v, log_i, log_f, gate = _mlstm_qkvif(p, h_in, cfg)
    nh = cfg.n_heads
    hd = q.shape[-1]
    if mode == "decode":
        hq, new_state = _mlstm_decode(q[:, 0], k[:, 0], v[:, 0],
                                      log_i[:, 0], log_f[:, 0], state)
        h = hq[:, None]
    else:
        h, new_state = _mlstm_chunk_scan(q, k, v, log_i, log_f, state)
    h = h.reshape(B, S, nh * hd).astype(x.dtype)
    out = (h * gate) @ p["w_down"]
    return x + out, new_state


def _mlstm_state(cfg, batch, dtype):
    nh = cfg.n_heads
    hd = (2 * cfg.d_model) // nh
    return (jnp.zeros((batch, nh, hd, hd), jnp.float32),
            jnp.zeros((batch, nh, hd), jnp.float32),
            jnp.full((batch, nh), _NEG, jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def _init_slstm(key, cfg, dtype):
    D = cfg.d_model
    nh = cfg.n_heads
    hd = D // nh
    ks = jax.random.split(key, 4)
    return {
        "ln": L.init_norm(ks[0], D, cfg.norm, dtype),
        "w_in": L.dense_init(ks[1], (D, 4 * D), dtype),
        "b_in": jnp.zeros((4 * D,), dtype),
        "r": L.dense_init(ks[2], (nh, hd, 4 * hd), dtype),
        "w_out": L.dense_init(ks[3], (D, D), dtype),
    }


def _slstm_step(p, cfg, pre_x, state):
    """One sLSTM step. pre_x: (B, 4D) input preactivation (x @ w_in + b)."""
    c, n, m, h = state                    # each (B, D)
    B, D4 = pre_x.shape
    D = D4 // 4
    nh = cfg.n_heads
    hd = D // nh
    hr = h.reshape(B, nh, hd)
    rec = jnp.einsum("bnh,nhk->bnk", hr, p["r"])        # (B, nh, 4*hd)
    # per-head (z,i,f,o) blocks -> global (z,i,f,o) layout matching w_in
    rec = rec.reshape(B, nh, 4, hd).swapaxes(1, 2).reshape(B, 4 * D)
    pre = pre_x + rec
    z, i_raw, f_raw, o_raw = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + m, i_raw)
    i_s = jnp.exp(i_raw - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(z)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new)


def _slstm_block(p, x, cfg, state, mode):
    B, S, D = x.shape
    h_in = L.apply_norm(x, p["ln"], cfg.norm, cfg.norm_eps)
    pre = (h_in @ p["w_in"] + p["b_in"])
    if mode == "decode":
        state = _slstm_step(p, cfg, pre[:, 0], state)
        hs = state[3][:, None]
    else:
        def step(st, px):
            st = _slstm_step(p, cfg, px, st)
            return st, st[3]
        state, hs = jax.lax.scan(step, state, pre.swapaxes(0, 1))
        hs = hs.swapaxes(0, 1)             # (B, S, D)
    out = hs.astype(x.dtype) @ p["w_out"]
    return x + out, state


def _slstm_state(cfg, batch, dtype):
    D = cfg.d_model
    def z():
        return jnp.zeros((batch, D), jnp.float32)
    return (z(), z(), jnp.full((batch, D), _NEG, jnp.float32), z())


# ---------------------------------------------------------------------------
# stack plumbing (same group-scan pattern as transformer.py)
# ---------------------------------------------------------------------------

def init_params(cfg, key, dtype=jnp.bfloat16):
    G = _n_groups(cfg)
    kinds = _slot_kinds(cfg)
    ks = jax.random.split(key, 3 + len(kinds))
    slots = []
    for i, kind in enumerate(kinds):
        init1 = _init_mlstm if kind == "mlstm" else _init_slstm
        layer_keys = jax.random.split(ks[3 + i], G)
        slots.append(jax.vmap(lambda k: init1(k, cfg, dtype))(layer_keys))
    return {
        "embed": L.embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
        "unembed": L.dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dtype),
        "final_norm": L.init_norm(ks[2], cfg.d_model, cfg.norm, dtype),
        "slots": tuple(slots),
    }


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
               window: Optional[int] = None):
    G = _n_groups(cfg)
    kinds = _slot_kinds(cfg)
    def stack(mk):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (G, *a.shape)), mk)
    slots = tuple(stack(_mlstm_state(cfg, batch, dtype) if k == "mlstm"
                        else _slstm_state(cfg, batch, dtype)) for k in kinds)
    return {"slots": slots, "pos": jnp.zeros((), jnp.int32)}


def _run_stack(params, x, cfg, mode, cache, remat=False):
    kinds = _slot_kinds(cfg)

    def body(carry, xs):
        x = carry
        slot_params, states = xs
        new_states = []
        for i, kind in enumerate(kinds):
            blk = _mlstm_block if kind == "mlstm" else _slstm_block
            x, st = blk(slot_params[i], x, cfg, states[i], mode)
            x = constrain(x, "batch", None, "d_model")
            new_states.append(st)
        return x, tuple(new_states)

    if remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)
    x, new_slots = jax.lax.scan(body, x, (params["slots"], cache["slots"]))
    return x, new_slots


def _embed(params, tokens):
    return constrain(jnp.take(params["embed"], tokens, axis=0),
                     "batch", None, "d_model")


def _logits(params, x, cfg):
    x = L.apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    return constrain(x @ params["unembed"], "batch", None, "vocab")


def forward_train(params, cfg, batch, *, window=None, remat=True):
    x = _embed(params, batch["tokens"])
    cache = init_cache(cfg, x.shape[0], 0, x.dtype)
    x, _ = _run_stack(params, x, cfg, "train", cache, remat=remat)
    return _logits(params, x, cfg), jnp.zeros((), jnp.float32)


def prefill(params, cfg, batch, cache, *, window=None):
    tokens = batch["tokens"]
    x = _embed(params, tokens)
    x, new_slots = _run_stack(params, x, cfg, "prefill", cache)
    last = _logits(params, x[:, -1:, :], cfg)[:, 0]
    return last, {"slots": new_slots,
                  "pos": jnp.asarray(tokens.shape[1], jnp.int32)}


def decode_step(params, cfg, token, cache, *, window=None):
    if token.ndim == 1:
        token = token[:, None]
    x = _embed(params, token)
    x, new_slots = _run_stack(params, x, cfg, "decode", cache)
    return _logits(params, x, cfg)[:, 0], {"slots": new_slots,
                                           "pos": cache["pos"] + 1}
