"""Shared neural-net layers: norms, RoPE, GQA attention (train/prefill/decode,
causal + sliding-window), MLPs, and parameter initializers.

All functions are pure; parameters are plain dict pytrees. Attention math is
done in fp32 regardless of the activation dtype.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                  # jax >= 0.6 exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                   # jax 0.4/0.5
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.models import sharding as SH
from repro.models.sharding import constrain


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dtype)


def layer_norm(x, w, b, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dtype)


def apply_norm(x, p, kind: str, eps: float):
    if kind == "layernorm":
        return layer_norm(x, p["w"], p["b"], eps)
    return rms_norm(x, p["w"], eps)


def init_norm(key, d, kind: str, dtype):
    if kind == "layernorm":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    return {"w": jnp.ones((d,), dtype)}


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                        # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions, d_model: int, dtype):
    """Whisper-style sinusoidal embeddings. positions: (...,)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------

def gqa_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                  q_pos_offset=0):
    """Full (train/prefill) GQA attention.

    q: (B, Sq, Hq, hd); k, v: (B, Skv, K, hd). Returns (B, Sq, Hq, hd).
    Causal masking uses absolute query position = q_pos_offset + row index.
    """
    B, Sq, Hq, hd = q.shape
    K = k.shape[2]
    G = Hq // K
    qf = q.reshape(B, Sq, K, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qf, kf) / math.sqrt(hd)
    qpos = q_pos_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: Optional[int] = None):
    """Single-token GQA attention over a KV cache.

    q: (B, 1, Hq, hd); caches: (B, Sc, K, hd) where Sc = max_len (no window)
    or Sc = window (rotating cache). ``pos`` is the current absolute position:
    a scalar, or a (B,) vector for continuous batching (per-slot positions).
    Keys in a rotating cache at slot j hold absolute position
    pos - ((pos - j) mod W); empty slots map to negative positions -> masked.
    """
    B, _, Hq, hd = q.shape
    Sc, K = k_cache.shape[1], k_cache.shape[2]
    G = Hq // K
    qf = q.reshape(B, K, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,btkh->bkgt", qf, k_cache.astype(jnp.float32))
    scores = scores / math.sqrt(hd)
    slots = jnp.arange(Sc)
    posv = jnp.asarray(pos)
    posb = posv if posv.ndim else posv[None]           # (B,) or (1,)
    if window is None:
        valid = slots[None, :] <= posb[:, None]        # (B|1, Sc)
    else:
        kpos = posb[:, None] - jnp.mod(posb[:, None] - slots[None, :], Sc)
        valid = kpos >= 0
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    # numerically-stable softmax; reduction over a (possibly sharded) Sc dim
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    probs = e / s
    out = jnp.einsum("bkgt,btkh->bkgh", probs, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, Hq, hd).astype(q.dtype)


def dist_decode_attention(q, k_cache, v_cache, k_new, v_new, pos):
    """Decode attention with the KV sequence dim sharded across the mesh
    'kv_seq' axis (flash-decoding across chips, TPU-idiomatic): each shard
    attends over its local KV chunk and the partial (max, sum, weighted-V)
    stats are combined with pmax/psum — bytes on the wire are O(B*H*hd),
    not O(KV). The cache write lands only on the owning shard.

    q, k_new, v_new: (B, 1, Hq|K, hd) replicated over the seq axis;
    caches: (B, Sc, K, hd) sharded on dim 1. pos: scalar.
    Returns (out (B,1,Hq,hd), k_cache, v_cache).
    """
    mesh = SH.mesh()
    seq_ax = SH.rule("kv_seq")
    batch_ax = SH.rule("kv_batch")
    B, _, Hq, hd = q.shape
    K = k_cache.shape[2]
    G = Hq // K
    n = mesh.shape[seq_ax]
    chunk = k_cache.shape[1] // n

    def body(qb, kc, vc, kn, vn):
        i = jax.lax.axis_index(seq_ax)
        off = i * chunk
        slot = pos - off
        ok = (slot >= 0) & (slot < chunk)
        idx = jnp.clip(slot, 0, chunk - 1)
        kc = kc.at[:, idx].set(jnp.where(ok, kn[:, 0], kc[:, idx]))
        vc = vc.at[:, idx].set(jnp.where(ok, vn[:, 0], vc[:, idx]))
        qf = qb.reshape(-1, K, G, hd).astype(jnp.float32)
        s = jnp.einsum("bkgh,btkh->bkgt", qf, kc.astype(jnp.float32))
        s = s / math.sqrt(hd)
        kpos = off + jnp.arange(chunk)
        s = jnp.where((kpos <= pos)[None, None, None, :], s, -1e30)
        m_loc = jnp.max(s, axis=-1)
        m = jax.lax.pmax(m_loc, seq_ax)                    # (b,K,G)
        e = jnp.exp(s - m[..., None])
        l = jax.lax.psum(jnp.sum(e, axis=-1), seq_ax)      # (b,K,G)
        o = jnp.einsum("bkgt,btkh->bkgh", e, vc.astype(jnp.float32))
        o = jax.lax.psum(o, seq_ax) / l[..., None]
        out = o.reshape(-1, 1, Hq, hd).astype(qb.dtype)
        return out, kc, vc

    def bspec(*rest):
        return P(batch_ax, *rest)
    out, kc, vc = _shard_map(
        body, mesh=mesh,
        in_specs=(bspec(None, None, None), bspec(seq_ax, None, None),
                  bspec(seq_ax, None, None), bspec(None, None, None),
                  bspec(None, None, None)),
        out_specs=(bspec(None, None, None), bspec(seq_ax, None, None),
                   bspec(seq_ax, None, None)),
    )(q, k_cache, v_cache, k_new, v_new)
    return out, kc, vc


def cache_update_decode(cache, new, pos, *, window: Optional[int] = None):
    """Write one token's k or v (B, 1, K, hd) into the cache at ``pos``
    (scalar, or (B,) per-slot positions for continuous batching)."""
    posv = jnp.asarray(pos)
    slot = posv if window is None else jnp.mod(posv, cache.shape[1])
    if posv.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new.astype(cache.dtype), slot, axis=1)
    B = cache.shape[0]
    return cache.at[jnp.arange(B), slot].set(new[:, 0].astype(cache.dtype))


def cache_fill_prefill(cache, k, *, window: Optional[int] = None):
    """Write a full prompt's keys/values (B, S, K, hd) into a fresh cache."""
    S, Sc = k.shape[1], cache.shape[1]
    if window is None or S <= Sc:
        if S > Sc:
            k = k[:, -Sc:]
            S = Sc
        return jax.lax.dynamic_update_slice_in_dim(cache, k.astype(cache.dtype), 0, axis=1)
    # rotating: keep last Sc tokens, token at abs pos p lands in slot p % Sc
    tail = k[:, -Sc:]                                  # positions [S-Sc, S)
    pos0 = S - Sc
    slots = jnp.mod(pos0 + jnp.arange(Sc), Sc)
    return cache.at[:, slots].set(tail.astype(cache.dtype))


# ---------------------------------------------------------------------------
# attention block (pre-norm residual)
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype, cross: bool = False):
    D, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (D, qd), dtype),
        "wk": dense_init(ks[1], (D, kvd), dtype),
        "wv": dense_init(ks[2], (D, kvd), dtype),
        "wo": dense_init(ks[3], (qd, D), dtype, scale=1.0 / math.sqrt(qd)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((qd,), dtype)
        p["bk"] = jnp.zeros((kvd,), dtype)
        p["bv"] = jnp.zeros((kvd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dtype)
    return p


def _project_qkv(p, x, cfg, positions, rope: bool):
    B = x.shape[0]
    S = x.shape[1]
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope and cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return q, k, v


def attn_forward(p, x, cfg, *, window=None, causal=True):
    """Full-sequence attention (train / prefill without cache)."""
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions, rope=True)
    out = gqa_attention(q, k, v, causal=causal, window=window)
    out = constrain(out, "batch", None, "heads", None)
    if _tp_axis_ok(cfg.n_heads, "heads"):
        return tp_attn_out(out, p["wo"], cfg)
    return out.reshape(x.shape[0], S, cfg.q_dim) @ p["wo"]


def attn_prefill(p, x, cfg, k_cache, v_cache, *, window=None):
    """Prefill: full attention + fill the cache. Returns (out, k_cache, v_cache)."""
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions, rope=True)
    out = gqa_attention(q, k, v, causal=True, window=window)
    k_cache = cache_fill_prefill(k_cache, k, window=window)
    v_cache = cache_fill_prefill(v_cache, v, window=window)
    out = out.reshape(x.shape[0], S, cfg.q_dim) @ p["wo"]
    return out, k_cache, v_cache


def attn_decode(p, x, cfg, k_cache, v_cache, pos, *, window=None):
    """Decode one token. x: (B, 1, D); pos scalar or (B,).
    Returns (out, k_cache, v_cache)."""
    posv = jnp.asarray(pos)
    if posv.ndim == 0:
        positions = jnp.full((x.shape[0], 1), posv)
    else:
        positions = posv[:, None]
    q, k, v = _project_qkv(p, x, cfg, positions, rope=True)
    if SH.rule("kv_seq") is not None and window is None and posv.ndim == 0:
        # seq-sharded KV: explicit flash-decoding across chips
        out, k_cache, v_cache = dist_decode_attention(q, k_cache, v_cache,
                                                      k, v, pos)
        out = out.reshape(x.shape[0], 1, cfg.q_dim) @ p["wo"]
        return out, k_cache, v_cache
    k_cache = cache_update_decode(k_cache, k, pos, window=window)
    v_cache = cache_update_decode(v_cache, v, pos, window=window)
    k_cache = constrain(k_cache, "kv_batch", "kv_seq", None, None)
    v_cache = constrain(v_cache, "kv_batch", "kv_seq", None, None)
    out = decode_attention(q, k_cache, v_cache, pos, window=window)
    out = out.reshape(x.shape[0], 1, cfg.q_dim) @ p["wo"]
    return out, k_cache, v_cache


def cross_attn_cache(p, enc_out, cfg):
    """Project encoder output to cross-attention K/V once (at prefill)."""
    B, S, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def cross_attn_apply(p, x, cfg, k, v):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    out = gqa_attention(q, k, v, causal=False)
    return out.reshape(B, S, cfg.q_dim) @ p["wo"]


# ---------------------------------------------------------------------------
# explicit-TP projections (bf16 all-reduce)
# ---------------------------------------------------------------------------
# XLA all-reduces the f32 matmul accumulator of a sharded contraction before
# converting to bf16 — doubling TP collective bytes. These shard_map variants
# convert the local partial product to bf16 *before* the psum, halving the
# wire bytes (standard TP trade: one bf16 rounding on the partial sums).
# Enabled by the 'tp_bf16_ar' rule; autodiff through shard_map keeps the
# backward psums in bf16 too.

def _tp_axis_ok(dim: int, axis_name: str = "d_ff") -> bool:
    ax = SH.rule(axis_name)
    m = SH.mesh()
    return bool(SH.rule("tp_bf16_ar") and ax is not None and m is not None
                and dim % m.shape[ax] == 0)


def tp_mlp_forward(p, x, cfg):
    """SwiGLU/GeLU FFN with explicit TP over the d_ff axis and bf16 psum."""
    ax = SH.rule("d_ff")
    mesh = SH.mesh()
    batch_ax = SH.rule("batch")

    def body(xl, *ws):
        if len(ws) == 3:
            wi, wg, wo = ws
            h = jax.nn.silu(xl @ wg) * (xl @ wi)
        else:
            wi, wo = ws
            h = jax.nn.gelu(xl @ wi)
        # bf16-native dot so the psum operand is born bf16 (no convert for
        # XLA's excess-precision pass to hoist past the collective)
        y = jax.lax.dot_general(h, wo, (((h.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=xl.dtype)
        return jax.lax.psum(y, ax)

    ws = (p["wi"], p["wg"], p["wo"]) if "wg" in p else (p["wi"], p["wo"])
    in_specs = [P(batch_ax, None, None)]
    for w in ws[:-1]:
        in_specs.append(P(None, ax))
    in_specs.append(P(ax, None))
    return _shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                         out_specs=P(batch_ax, None, None))(x, *ws)


def tp_attn_out(out_heads, wo, cfg):
    """Attention output projection (B,S,Hq,hd)@(Hq*hd,D) with heads sharded
    over the model axis and a bf16 psum."""
    ax = SH.rule("heads")
    mesh = SH.mesh()
    batch_ax = SH.rule("batch")
    n = mesh.shape[ax]
    hd = cfg.head_dim

    def body(ol, wl):
        B, S, hl, _ = ol.shape
        y = (ol.reshape(B, S, hl * hd) @ wl).astype(ol.dtype)
        return jax.lax.psum(y, ax)

    del n
    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_ax, None, ax, None), P(ax, None)),
        out_specs=P(batch_ax, None, None),
    )(out_heads, wo)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, dtype, d_ff: Optional[int] = None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {
            "wi": dense_init(ks[0], (D, F), dtype),
            "wg": dense_init(ks[1], (D, F), dtype),
            "wo": dense_init(ks[2], (F, D), dtype, scale=1.0 / math.sqrt(F)),
        }
    return {
        "wi": dense_init(ks[0], (D, F), dtype),
        "wo": dense_init(ks[2], (F, D), dtype, scale=1.0 / math.sqrt(F)),
    }


def mlp_forward(p, x, cfg):
    if _tp_axis_ok(p["wi"].shape[-1]):
        return tp_mlp_forward(p, x, cfg)
    if "wg" in p:
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    h = constrain(h, "batch", None, "d_ff")
    return h @ p["wo"]
