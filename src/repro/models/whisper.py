"""Whisper-style encoder-decoder audio backbone. [arXiv:2212.04356]

Per the assignment carve-out, the mel-spectrogram + conv feature extractor is
a STUB: ``batch["enc_feats"]`` supplies precomputed frame embeddings
(B, encoder_seq, d_model). Everything downstream — 32-layer bidirectional
encoder, 32-layer causal decoder with self- and cross-attention KV caches —
is implemented here. Positions are sinusoidal (Whisper's encoder is
sinusoidal; its decoder uses learned positions — we use sinusoidal there too
so the position table does not dominate memory at the assignment's 32k/500k
decode shapes; recorded in DESIGN.md).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.sharding import constrain


def _init_enc_layer(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    return {
        "ln1": L.init_norm(ks[0], cfg.d_model, cfg.norm, dtype),
        "ln2": L.init_norm(ks[1], cfg.d_model, cfg.norm, dtype),
        "attn": L.init_attention(ks[2], cfg, dtype),
        "ffn": L.init_mlp(ks[3], cfg, dtype),
    }


def _init_dec_layer(key, cfg, dtype):
    ks = jax.random.split(key, 6)
    return {
        "ln1": L.init_norm(ks[0], cfg.d_model, cfg.norm, dtype),
        "ln_c": L.init_norm(ks[1], cfg.d_model, cfg.norm, dtype),
        "ln2": L.init_norm(ks[2], cfg.d_model, cfg.norm, dtype),
        "attn": L.init_attention(ks[3], cfg, dtype),
        "cross": L.init_attention(ks[4], cfg, dtype, cross=True),
        "ffn": L.init_mlp(ks[5], cfg, dtype),
    }


def init_params(cfg, key, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[3], cfg.n_encoder_layers)
    dec_keys = jax.random.split(ks[4], cfg.n_layers)
    return {
        "embed": L.embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
        "unembed": L.dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dtype),
        "final_norm": L.init_norm(ks[2], cfg.d_model, cfg.norm, dtype),
        "enc_norm": L.init_norm(ks[5], cfg.d_model, cfg.norm, dtype),
        "enc": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(enc_keys),
        "dec": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(dec_keys),
    }


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
               window: Optional[int] = None):
    Ld = cfg.n_layers
    Sc = min(max_len, window) if window else max_len
    def kv(s):
        return jnp.zeros((Ld, batch, s, cfg.n_kv_heads, cfg.head_dim), dtype)
    return {
        "k": kv(Sc), "v": kv(Sc),
        "ck": kv(cfg.encoder_seq), "cv": kv(cfg.encoder_seq),
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------

def encode(params, cfg, enc_feats):
    x = enc_feats + L.sinusoidal_pos(jnp.arange(enc_feats.shape[1]),
                                     cfg.d_model, enc_feats.dtype)
    x = constrain(x, "batch", None, "d_model")

    def body(x, p):
        h = L.apply_norm(x, p["ln1"], cfg.norm, cfg.norm_eps)
        x = x + L.attn_forward(p["attn"], h, cfg, causal=False)
        h = L.apply_norm(x, p["ln2"], cfg.norm, cfg.norm_eps)
        x = x + L.mlp_forward(p["ffn"], h, cfg)
        return constrain(x, "batch", None, "d_model"), None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return L.apply_norm(x, params["enc_norm"], cfg.norm, cfg.norm_eps)


def _dec_embed(params, cfg, tokens, pos0):
    x = jnp.take(params["embed"], tokens, axis=0)
    p0 = jnp.asarray(pos0)
    if p0.ndim == 0:
        positions = (p0 + jnp.arange(tokens.shape[1]))[None, :]
    else:                                  # per-slot positions (B,)
        positions = p0[:, None] + jnp.arange(tokens.shape[1])[None, :]
    x = x + L.sinusoidal_pos(positions, cfg.d_model, x.dtype)
    return constrain(x, "batch", None, "d_model")


def _dec_stack(params, cfg, x, mode, cache, enc_out=None, window=None,
               remat=False):
    """mode: train|prefill|decode. For prefill, enc_out is required (cross K/V
    are computed and stored); for decode they are read from the cache."""
    pos = cache["pos"] if cache is not None else 0

    def body(x, xs):
        if mode == "train":
            p = xs
        else:
            p, kc, vc, ck, cv = xs
        h = L.apply_norm(x, p["ln1"], cfg.norm, cfg.norm_eps)
        if mode == "train":
            a = L.attn_forward(p["attn"], h, cfg, window=window)
            new = None
        elif mode == "prefill":
            a, kc, vc = L.attn_prefill(p["attn"], h, cfg, kc, vc, window=window)
            ck, cv = L.cross_attn_cache(p["cross"], enc_out, cfg)
            new = (kc, vc, ck, cv)
        else:
            a, kc, vc = L.attn_decode(p["attn"], h, cfg, kc, vc, pos,
                                      window=window)
            new = (kc, vc, ck, cv)
        x = x + a
        h = L.apply_norm(x, p["ln_c"], cfg.norm, cfg.norm_eps)
        if mode == "train":
            x = x + L.cross_attn_apply(p["cross"], h, cfg,
                                       *L.cross_attn_cache(p["cross"], enc_out, cfg))
        else:
            x = x + L.cross_attn_apply(p["cross"], h, cfg, ck, cv)
        h = L.apply_norm(x, p["ln2"], cfg.norm, cfg.norm_eps)
        x = x + L.mlp_forward(p["ffn"], h, cfg)
        return constrain(x, "batch", None, "d_model"), new

    if mode == "train":
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["dec"])
        return x, None
    xs = (params["dec"], cache["k"], cache["v"], cache["ck"], cache["cv"])
    x, new = jax.lax.scan(body, x, xs)
    return x, new


def _logits(params, x, cfg):
    x = L.apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    return constrain(x @ params["unembed"], "batch", None, "vocab")


def forward_train(params, cfg, batch, *, window=None, remat=True):
    enc_out = encode(params, cfg, batch["enc_feats"])
    x = _dec_embed(params, cfg, batch["tokens"], 0)
    x, _ = _dec_stack(params, cfg, x, "train", None, enc_out=enc_out,
                      window=window, remat=remat)
    return _logits(params, x, cfg), jnp.zeros((), jnp.float32)


def prefill(params, cfg, batch, cache, *, window=None):
    enc_out = encode(params, cfg, batch["enc_feats"])
    tokens = batch["tokens"]
    x = _dec_embed(params, cfg, tokens, 0)
    x, new = _dec_stack(params, cfg, x, "prefill", cache, enc_out=enc_out,
                        window=window)
    kc, vc, ck, cv = new
    last = _logits(params, x[:, -1:, :], cfg)[:, 0]
    return last, {"k": kc, "v": vc, "ck": ck, "cv": cv,
                  "pos": jnp.asarray(tokens.shape[1], jnp.int32)}


def decode_step(params, cfg, token, cache, *, window=None):
    if token.ndim == 1:
        token = token[:, None]
    x = _dec_embed(params, cfg, token, cache["pos"])
    x, new = _dec_stack(params, cfg, x, "decode", cache, window=window)
    kc, vc, ck, cv = new
    return _logits(params, x, cfg)[:, 0], {"k": kc, "v": vc, "ck": ck,
                                           "cv": cv, "pos": cache["pos"] + 1}
