from repro.models.api import LM, make_batch_specs, make_demo_batch

__all__ = ["LM", "make_batch_specs", "make_demo_batch"]
