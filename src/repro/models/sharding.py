"""Logical-axis sharding annotations (MaxText-style, minimal).

Model code annotates activations with *logical* axis names; the launcher
installs a rules table mapping logical names -> mesh axes. Outside a rules
context (CPU smoke tests) annotations are no-ops, so model code is written
once and runs both places.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# logical axis -> mesh axes. Installed by the launcher.
_RULES: Optional[Dict[str, MeshAxes]] = None
_MESH = None

# Canonical rule sets -------------------------------------------------------

def standard_rules(multi_pod: bool) -> Dict[str, MeshAxes]:
    """2D (data, model) sharding; batch additionally over the pod axis."""
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "seq": None,           # sequence replicated by default (see kv_seq)
        "d_model": None,       # activations replicated over model on entry
        "heads": "model",
        "kv_heads": None,      # GQA: kv heads usually < model axis -> replicate
        "d_ff": "model",
        "experts": "model",
        "vocab": "model",
        "kv_batch": batch,     # kv-cache batch dim
        "kv_seq": None,        # set to "model" for seq-sharded long-KV decode
        "lru": "model",        # RG-LRU / mLSTM inner width
    }


@contextlib.contextmanager
def use_rules(rules: Dict[str, MeshAxes], mesh=None):
    global _RULES, _MESH
    prev, prev_mesh = _RULES, _MESH
    _RULES, _MESH = rules, mesh
    try:
        yield
    finally:
        _RULES, _MESH = prev, prev_mesh


def logical_to_spec(axes: Sequence[Optional[str]]) -> P:
    assert _RULES is not None
    return P(*[_RULES.get(a) if a is not None else None for a in axes])


def constrain(x, *axes: Optional[str]):
    """Annotate ``x`` with logical axes (one per dim; None = replicated)."""
    if _RULES is None:
        return x
    spec = logical_to_spec(axes)
    if _MESH is not None:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(_MESH, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def active() -> bool:
    return _RULES is not None


def rule(name: str):
    """Mesh axes mapped to a logical axis (None outside a rules context)."""
    return _RULES.get(name) if _RULES is not None else None


def maybe_gather_params(p):
    """ZeRO-3 / FSDP: when the 'fsdp_gather' rule is set, constrain the
    current layer's weight slices to replicated — GSPMD materializes an
    all-gather here (and a reduce-scatter for the grads in the backward),
    so only one layer's weights are ever live replicated inside the scan."""
    if _RULES is None or not _RULES.get("fsdp_gather"):
        return p
    import jax.numpy as jnp  # noqa: F401

    def repl(x):
        spec = P(*(None,) * x.ndim)
        if _MESH is not None:
            return jax.lax.with_sharding_constraint(
                x, jax.sharding.NamedSharding(_MESH, spec))
        return x
    return jax.tree.map(repl, p)


def mesh():
    return _MESH
