"""Unified model API: one entry point per family, shared loss/sampling.

Usage:
    lm = LM(cfg)
    params = lm.init(key, dtype)
    logits, aux = lm.forward_train(params, batch)
    loss = lm.loss(params, batch)
    cache = lm.init_cache(batch_size, max_len)
    logits, cache = lm.prefill(params, batch, cache)
    logits, cache = lm.decode_step(params, token, cache)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import rglru, transformer, whisper, xlstm

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "audio": whisper,
    "ssm": xlstm,
    "hybrid": rglru,
}

MOE_AUX_WEIGHT = 0.01


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.mod = _FAMILY_MODULES[cfg.family]

    # -- params / cache ------------------------------------------------------
    def init(self, key, dtype=jnp.float32):
        return self.mod.init_params(self.cfg, key, dtype)

    def init_abstract(self, dtype=jnp.bfloat16):
        """Parameter ShapeDtypeStructs without allocating (for dry-runs)."""
        return jax.eval_shape(
            lambda k: self.mod.init_params(self.cfg, k, dtype),
            jax.random.key(0))

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   window: Optional[int] = None):
        return self.mod.init_cache(self.cfg, batch, max_len, dtype,
                                   window=window)

    # -- forward passes ------------------------------------------------------
    def forward_train(self, params, batch, *, window=None, remat=True):
        return self.mod.forward_train(params, self.cfg, batch, window=window,
                                      remat=remat)

    def prefill(self, params, batch, cache, *, window=None):
        return self.mod.prefill(params, self.cfg, batch, cache, window=window)

    def decode_step(self, params, token, cache, *, window=None):
        return self.mod.decode_step(params, self.cfg, token, cache,
                                    window=window)

    # -- losses ---------------------------------------------------------------
    def loss(self, params, batch, *, window=None, remat=True):
        """Causal LM loss: tokens predict labels; labels < 0 are masked."""
        logits, aux = self.forward_train(params, batch, window=window,
                                         remat=remat)
        labels = batch["labels"]
        lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(labels, 0)[..., None], axis=-1
        )[..., 0].astype(jnp.float32)
        nll = lse - gold
        mask = (labels >= 0).astype(jnp.float32)
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss + MOE_AUX_WEIGHT * aux


def make_batch_specs(cfg: ModelConfig, batch: int, seq: int,
                     dtype=jnp.bfloat16, with_labels: bool = True):
    """ShapeDtypeStruct stand-ins for a training/prefill batch."""
    specs = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if with_labels:
        specs["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if cfg.is_encoder_decoder:
        specs["enc_feats"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), dtype)
    return specs


def make_demo_batch(cfg: ModelConfig, batch: int, seq: int, key,
                    dtype=jnp.float32):
    """Concrete random batch for smoke tests / examples."""
    k1, k2 = jax.random.split(key)
    out = {
        "tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size),
    }
    out["labels"] = jnp.roll(out["tokens"], -1, axis=1).at[:, -1].set(-1)
    if cfg.is_encoder_decoder:
        out["enc_feats"] = jax.random.normal(
            k2, (batch, cfg.encoder_seq, cfg.d_model), dtype) * 0.02
    return out
