"""Griffin-style hybrid family (RecurrentGemma): RG-LRU recurrent blocks +
local (sliding-window) attention, pattern ("rec","rec","attn"). [arXiv:2402.19427]

Full-period groups are scanned; leftover layers (26 mod 3 = 2) are unrolled.
Train/prefill runs the linear recurrence with ``jax.lax.associative_scan``
(parallel, TPU-friendly); decode is the exact one-step recurrence. The
recurrent state (B, W) plus a (conv_width-1) conv tail is the entire
"KV cache" of a rec layer — constant in sequence length, which is why this
family runs ``long_500k`` natively.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.sharding import constrain

_N_BLOCKS = 8   # block-diagonal gate projections (Griffin Appendix A)
_LRU_C = 8.0


def _pattern(cfg):
    return cfg.attn_pattern or ("rec", "rec", "attn")


def _plan(cfg):
    pat = _pattern(cfg)
    G = cfg.n_layers // len(pat)
    rest = tuple(pat[: cfg.n_layers - G * len(pat)])
    return G, pat, rest


# ---------------------------------------------------------------------------
# RG-LRU recurrent block
# ---------------------------------------------------------------------------

def _init_rec(key, cfg, dtype):
    D = cfg.d_model
    W = cfg.lru_width or D
    ks = jax.random.split(key, 8)
    nb = _N_BLOCKS
    # Lambda init so that a = exp(-c*softplus(L)) ** sigmoid(r) spans ~(0.9, 0.999)
    u = jax.random.uniform(ks[0], (W,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u ** _LRU_C) / _LRU_C))
    return {
        "ln": L.init_norm(ks[1], D, cfg.norm, dtype),
        "w_gate": L.dense_init(ks[2], (D, W), dtype),
        "w_in": L.dense_init(ks[3], (D, W), dtype),
        "conv_w": L.dense_init(ks[4], (cfg.conv_width, W), dtype, scale=0.1),
        "conv_b": jnp.zeros((W,), dtype),
        "w_r": L.dense_init(ks[5], (nb, W // nb, W // nb), dtype),
        "w_i": L.dense_init(ks[6], (nb, W // nb, W // nb), dtype),
        "b_r": jnp.zeros((W,), dtype),
        "b_i": jnp.zeros((W,), dtype),
        "lam": lam.astype(jnp.float32),
        "w_out": L.dense_init(ks[7], (W, D), dtype),
    }


def _block_diag(u, w):
    """u: (..., W) @ block-diagonal w: (nb, W/nb, W/nb) -> (..., W)."""
    nb, bs, _ = w.shape
    shape = u.shape
    ub = u.reshape(*shape[:-1], nb, bs)
    out = jnp.einsum("...nb,nbk->...nk", ub, w)
    return out.reshape(*shape)


def _lru_gates(p, u):
    """Return (log_a, x_scaled) both (..., W) fp32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_diag(uf, p["w_r"].astype(jnp.float32)) + p["b_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(uf, p["w_i"].astype(jnp.float32)) + p["b_i"].astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(p["lam"]) * r            # (...,W) < 0
    a_sq = jnp.exp(2.0 * log_a)
    x = jnp.sqrt(jnp.maximum(1.0 - a_sq, 1e-12)) * (i * uf)
    return log_a, x


def _causal_conv(u, w, b, tail=None):
    """Depthwise causal conv. u: (B,S,W); w: (cw,W); tail: (B,cw-1,W)."""
    cw = w.shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    full = jnp.concatenate([tail, u], axis=1)
    out = sum(full[:, j:j + u.shape[1]] * w[j] for j in range(cw))
    new_tail = full[:, -(cw - 1):] if cw > 1 else tail
    return out + b, new_tail


def _rec_block(p, x, cfg, state, mode):
    B, S, D = x.shape
    h_in = L.apply_norm(x, p["ln"], cfg.norm, cfg.norm_eps)
    gate = jax.nn.gelu(h_in @ p["w_gate"])
    u = h_in @ p["w_in"]
    gate = constrain(gate, "batch", None, "lru")
    u = constrain(u, "batch", None, "lru")
    conv_tail, h_lru = state
    u, conv_tail = _causal_conv(u, p["conv_w"], p["conv_b"], conv_tail)
    log_a, xs = _lru_gates(p, u)
    if mode == "decode":
        h_new = jnp.exp(log_a[:, 0]) * h_lru + xs[:, 0]        # (B,W)
        y = h_new[:, None]
        state = (conv_tail, h_new)
    else:
        # h_t = a_t h_{t-1} + x_t ; associative scan over S, fp32
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return (a2 + a1, b2 + jnp.exp(a2) * b1)
        la, xb = jax.lax.associative_scan(combine, (log_a, xs), axis=1)
        y = xb + jnp.exp(la) * h_lru[:, None]
        state = (conv_tail, y[:, -1])
    out = (y.astype(x.dtype) * gate) @ p["w_out"]
    return x + out, state


def _rec_state(cfg, batch, dtype):
    W = cfg.lru_width or cfg.d_model
    return (jnp.zeros((batch, cfg.conv_width - 1, W), dtype),
            jnp.zeros((batch, W), jnp.float32))


# ---------------------------------------------------------------------------
# attention + mlp slots (reuse shared layers)
# ---------------------------------------------------------------------------

def _init_attn_slot(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    return {
        "ln1": L.init_norm(ks[0], cfg.d_model, cfg.norm, dtype),
        "ln2": L.init_norm(ks[1], cfg.d_model, cfg.norm, dtype),
        "attn": L.init_attention(ks[2], cfg, dtype),
        "ffn": L.init_mlp(ks[3], cfg, dtype),
    }


def _init_rec_slot(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "rec": _init_rec(ks[0], cfg, dtype),
        "ln2": L.init_norm(ks[1], cfg.d_model, cfg.norm, dtype),
        "ffn": L.init_mlp(ks[2], cfg, dtype),
    }


def _attn_apply(p, x, cfg, cache, mode, pos):
    h = L.apply_norm(x, p["ln1"], cfg.norm, cfg.norm_eps)
    w = cfg.window
    if mode == "train":
        a, new_cache = L.attn_forward(p["attn"], h, cfg, window=w), cache
    elif mode == "prefill":
        a, kc, vc = L.attn_prefill(p["attn"], h, cfg, cache["k"], cache["v"],
                                   window=w)
        new_cache = {"k": kc, "v": vc}
    else:
        a, kc, vc = L.attn_decode(p["attn"], h, cfg, cache["k"], cache["v"],
                                  pos, window=w)
        new_cache = {"k": kc, "v": vc}
    x = x + a
    h = L.apply_norm(x, p["ln2"], cfg.norm, cfg.norm_eps)
    x = x + L.mlp_forward(p["ffn"], h, cfg)
    return constrain(x, "batch", None, "d_model"), new_cache


def _rec_apply(p, x, cfg, state, mode, pos):
    x, new_state = _rec_block(p["rec"], x, cfg, state, mode)
    h = L.apply_norm(x, p["ln2"], cfg.norm, cfg.norm_eps)
    x = x + L.mlp_forward(p["ffn"], h, cfg)
    return constrain(x, "batch", None, "d_model"), new_state


def _slot_cache(cfg, kind, batch, max_len, dtype, window):
    if kind == "rec":
        return _rec_state(cfg, batch, dtype)
    Sc = min(max_len, window or cfg.window or max_len)
    def z():
        return jnp.zeros((batch, Sc, cfg.n_kv_heads, cfg.head_dim), dtype)
    return {"k": z(), "v": z()}


# ---------------------------------------------------------------------------
# stack
# ---------------------------------------------------------------------------

def init_params(cfg, key, dtype=jnp.bfloat16):
    G, pat, rest = _plan(cfg)
    ks = jax.random.split(key, 3 + len(pat) + len(rest))
    init1 = {"rec": _init_rec_slot, "attn": _init_attn_slot}
    slots = []
    for i, kind in enumerate(pat):
        layer_keys = jax.random.split(ks[3 + i], G)
        slots.append(jax.vmap(lambda k: init1[kind](k, cfg, dtype))(layer_keys))
    rest_params = tuple(init1[kind](ks[3 + len(pat) + j], cfg, dtype)
                        for j, kind in enumerate(rest))
    return {
        "embed": L.embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
        "unembed": L.dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dtype),
        "final_norm": L.init_norm(ks[2], cfg.d_model, cfg.norm, dtype),
        "slots": tuple(slots),
        "rest": rest_params,
    }


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
               window: Optional[int] = None):
    G, pat, rest = _plan(cfg)
    def stack(c):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (G, *a.shape)), c)
    return {
        "slots": tuple(stack(_slot_cache(cfg, k, batch, max_len, dtype, window))
                       for k in pat),
        "rest": tuple(_slot_cache(cfg, k, batch, max_len, dtype, window)
                      for k in rest),
        "pos": jnp.zeros((), jnp.int32),
    }


def _run_stack(params, x, cfg, mode, cache, remat=False):
    G, pat, rest = _plan(cfg)
    apply1 = {"rec": _rec_apply, "attn": _attn_apply}
    pos = cache["pos"] if cache is not None else 0

    def body(x, xs):
        slot_params, caches = xs
        new = []
        for i, kind in enumerate(pat):
            x, st = apply1[kind](slot_params[i], x, cfg,
                                 caches[i] if caches is not None else None,
                                 mode, pos)
            new.append(st)
        return x, tuple(new)

    if remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)
    caches = cache["slots"] if cache is not None else tuple(
        _slot_cache(cfg, k, x.shape[0], 0, x.dtype, None) for k in pat)
    if mode == "train":
        caches = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (G, *a.shape)), tuple(
                _slot_cache(cfg, k, x.shape[0], 1, x.dtype, None) for k in pat))
    x, new_slots = jax.lax.scan(body, x, (params["slots"], caches))
    new_rest = []
    rest_caches = cache["rest"] if cache is not None else [None] * len(rest)
    for j, kind in enumerate(rest):
        rc = rest_caches[j] if mode != "train" else \
            _slot_cache(cfg, kind, x.shape[0], 1, x.dtype, None)
        x, st = apply1[kind](params["rest"][j], x, cfg, rc, mode, pos)
        new_rest.append(st)
    return x, new_slots, tuple(new_rest)


def _embed(params, tokens):
    return constrain(jnp.take(params["embed"], tokens, axis=0),
                     "batch", None, "d_model")


def _logits(params, x, cfg):
    x = L.apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    return constrain(x @ params["unembed"], "batch", None, "vocab")


def forward_train(params, cfg, batch, *, window=None, remat=True):
    x = _embed(params, batch["tokens"])
    x, _, _ = _run_stack(params, x, cfg, "train", None, remat=remat)
    return _logits(params, x, cfg), jnp.zeros((), jnp.float32)


def prefill(params, cfg, batch, cache, *, window=None):
    tokens = batch["tokens"]
    x = _embed(params, tokens)
    x, slots, rest = _run_stack(params, x, cfg, "prefill", cache)
    last = _logits(params, x[:, -1:, :], cfg)[:, 0]
    return last, {"slots": slots, "rest": rest,
                  "pos": jnp.asarray(tokens.shape[1], jnp.int32)}


def decode_step(params, cfg, token, cache, *, window=None):
    if token.ndim == 1:
        token = token[:, None]
    x = _embed(params, token)
    x, slots, rest = _run_stack(params, x, cfg, "decode", cache)
    return _logits(params, x, cfg)[:, 0], {"slots": slots, "rest": rest,
                                           "pos": cache["pos"] + 1}
