"""Capacity-based top-k MoE FFN (GShard-style dense dispatch).

Tokens are grouped (``moe_group_size`` per group); each group dispatches to
experts with capacity C = ceil(group * capacity_factor * k / E). Dispatch is
an einsum against a one-hot (group, E, C) tensor, which XLA SPMD shards over
the ``experts`` (= model) mesh axis — the expert-parallel pattern. Overflow
tokens are dropped (residual passes through), matching Switch/GShard.

Returns an aux load-balancing loss (Switch eq. 4) accumulated by the caller.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.sharding import constrain


def init_moe(key, cfg, dtype):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), dtype, scale=0.02),
        "wi": dense_init(ks[1], (E, D, F), dtype),
        "wo": dense_init(ks[2], (E, F, D), dtype, scale=1.0 / math.sqrt(F)),
    }
    if cfg.mlp == "swiglu":
        p["wg"] = dense_init(ks[3], (E, D, F), dtype)
    if cfg.shared_expert:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], cfg, dtype)
    return p


def _capacity(group: int, cfg) -> int:
    return max(1, int(math.ceil(group * cfg.capacity_factor * cfg.top_k
                                / cfg.n_experts)))


def moe_forward(p, x, cfg, dropless: bool = False):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar).

    ``dropless=True`` (decode) sets capacity = group size, so no token can
    overflow — exact routing at O(batch) extra dispatch cost. Train/prefill
    use GShard capacity dropping.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    g = min(cfg.moe_group_size, B * S)
    T = B * S
    # pad so the flat token stream divides into groups
    n_groups = -(-T // g)
    pad = n_groups * g - T
    xf = x.reshape(T, D)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    xg = xf.reshape(n_groups, g, D)
    C = g if dropless else _capacity(g, cfg)

    logits = (xg @ p["router"].astype(jnp.float32)
              if p["router"].dtype != jnp.float32
              else xg @ p["router"]).astype(jnp.float32)   # (N, g, E)
    gates = jax.nn.softmax(logits, axis=-1)

    # --- top-k routing with per-expert capacity ---------------------------
    dispatch = jnp.zeros((n_groups, g, E, C), dtype=xg.dtype)
    combine = jnp.zeros((n_groups, g, E, C), dtype=jnp.float32)
    masked_gates = gates
    counts = jnp.zeros((n_groups, 1, E), dtype=jnp.int32)
    gate_sum = jnp.zeros((n_groups, g), dtype=jnp.float32)
    sel_onehots = []
    for _ in range(k):
        idx = jnp.argmax(masked_gates, axis=-1)                 # (N, g)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)        # (N, g, E)
        sel_onehots.append(onehot)
        gate_j = jnp.sum(gates * onehot, axis=-1)               # (N, g)
        # position of each routed token within its expert's capacity
        pos = jnp.cumsum(onehot, axis=1) - onehot + counts      # (N, g, E)
        within = (pos < C) & (onehot > 0)
        pos_sel = jnp.sum(pos * onehot, axis=-1)                # (N, g)
        fits = jnp.sum(jnp.where(within, 1, 0), axis=-1) > 0    # (N, g)
        pos_oh = jax.nn.one_hot(pos_sel, C, dtype=xg.dtype)     # (N, g, C)
        d_j = (onehot.astype(xg.dtype)[..., None] * pos_oh[:, :, None, :])
        d_j = d_j * fits.astype(xg.dtype)[:, :, None, None]
        dispatch = dispatch + d_j
        combine = combine + d_j.astype(jnp.float32) * gate_j[:, :, None, None]
        gate_sum = gate_sum + gate_j * fits.astype(jnp.float32)
        counts = counts + jnp.sum(jnp.where(within, onehot, 0), axis=1,
                                  keepdims=True)
        masked_gates = masked_gates * (1 - onehot.astype(jnp.float32))
    # renormalize combine weights over the selected experts
    combine = combine / jnp.maximum(gate_sum, 1e-9)[:, :, None, None]
    combine = combine.astype(xg.dtype)

    # --- aux load-balance loss (Switch eq. 4) ------------------------------
    sel = sum(sel_onehots).astype(jnp.float32)
    frac_tokens = jnp.mean(sel, axis=1)                          # (N, E)
    frac_probs = jnp.mean(gates, axis=1)                         # (N, E)
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1)) / k

    # --- expert computation -------------------------------------------------
    # N (the group dim, carrying the batch) stays sharded over the data axes
    # while E shards over the model axis: expert-parallel x data-parallel.
    xe = jnp.einsum("ngd,ngec->ecnd", xg, dispatch)              # (E, C, N, D)
    xe = constrain(xe, "experts", None, "batch", None)
    if "wg" in p:
        h = jax.nn.silu(jnp.einsum("ecnd,edf->ecnf", xe, p["wg"]))
        h = h * jnp.einsum("ecnd,edf->ecnf", xe, p["wi"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecnd,edf->ecnf", xe, p["wi"]))
    h = constrain(h, "experts", None, "batch", None)
    ye = jnp.einsum("ecnf,efd->ecnd", h, p["wo"])                # (E, C, N, D)
    ye = constrain(ye, "experts", None, "batch", None)
    out = jnp.einsum("ecnd,ngec->ngd", ye, combine)              # (N, g, D)
    out = constrain(out, "batch", None, None)

    out = out.reshape(n_groups * g, D)
    if pad:
        out = out[:T]
    out = out.reshape(B, S, D)
    if "shared" in p:
        from repro.models.layers import mlp_forward
        out = out + mlp_forward(p["shared"], x, cfg)
    return out, aux
