"""Jitted public wrapper for flash attention with GQA support and a pure-jnp
fallback (used on CPU / in dry-runs; the Pallas path targets TPU)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def _expand_gqa(q, k, v):
    B, S, Hq, hd = q.shape
    K = k.shape[2]
    if K != Hq:
        rep = Hq // K
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return q, k, v


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, impl: str = "pallas",
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """GQA flash attention. q: (B,S,Hq,hd); k,v: (B,S,K,hd), K | Hq."""
    q, k, v = _expand_gqa(q, k, v)
    if impl == "pallas":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      bq=bq, bk=bk, interpret=interpret)
    return flash_attention_ref(q, k, v, causal=causal, window=window)
