"""Pure-jnp oracle for blocked causal/windowed flash attention."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None):
    """q,k,v: (B, S, H, hd) (same H: GQA expansion is done by the caller).
    Returns (B, S, H, hd) in q.dtype; math in fp32."""
    B, S, H, hd = q.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bshd,bthd->bhst", qf, kf) / math.sqrt(hd)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
