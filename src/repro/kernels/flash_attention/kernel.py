"""Pallas TPU flash-attention (prefill) kernel.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * the KV loop is the minor-most *grid* dimension, not an in-kernel loop —
    the TPU grid executes sequentially per core, so VMEM scratch
    (acc, m, l) persists across KV steps and plays the role of the CUDA
    thread-block registers;
  * block shapes are MXU-aligned (multiples of 128 on the matmul dims) and
    sized so q/k/v/acc tiles fit VMEM (~16 MB): bq=bk=128, hd<=256 claims
    ~0.5 MB across the four live tiles;
  * there is no warp-shuffle reduction: row max/sum are plain vector
    reductions over the lane dimension, which the VPU does natively.

Causal + sliding-window masking is applied inside the kernel; with causal
masking, KV blocks strictly above the diagonal are skipped via @pl.when.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, causal: bool, window: Optional[int],
               bq: int, bk: int, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk

    def body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                   # (bk, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    if causal:
        # skip blocks entirely above the causal diagonal
        @pl.when(k_start <= q_start + bq - 1)
        def _run():
            body()
    else:
        body()

    @pl.when(ki == n_kv - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True):
    """q,k,v: (B, S, H, hd) with identical H (GQA expansion done by caller).
    Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    assert k.shape == v.shape == (B, S, H, hd)
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    scale = 1.0 / math.sqrt(hd)

    # fold (B, H) into one grid axis; per-step tiles are (1, bq/bk, hd)
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    kernel = functools.partial(_fa_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, n_kv=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
