"""Pallas TPU split-KV decode-attention kernel (flash-decoding style).

The long KV cache is split across the minor grid dimension; a VMEM scratch
accumulator carries the running (max, sum, weighted-V) across KV blocks —
the TPU-idiomatic replacement for the GPU flash-decoding pattern, where
partial results from thread blocks are combined by a second reduction
kernel (warp shuffles have no TPU analogue; the sequential grid + VMEM
scratch achieves the same reduction without a second pass).

GQA layout: queries arrive as (B, K, G, hd) — one kernel instance per
(batch, kv-head); the G query heads sharing that KV head are processed as
the matmul's row dimension, so the KV block is loaded once per G rows
(the GQA arithmetic-intensity win, preserved in VMEM).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                *, scale: float, bs: int, n_kv: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0]
    start = si * bs

    @pl.when(start <= pos)
    def _run():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (G, hd)
        k = k_ref[0][:, 0, :].astype(jnp.float32)          # (bs, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bs)
        kpos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0][:, 0, :].astype(jnp.float32)          # (bs, hd)
        acc_ref[...] = acc_ref[...] * alpha + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(si == n_kv - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, pos, *, bs: int = 512,
                            interpret: bool = True):
    """q: (B, Hq, hd); caches (B, S, K, hd); pos scalar int32.
    Returns (B, Hq, hd)."""
    B, Hq, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = Hq // K
    bs = min(bs, S)
    assert S % bs == 0
    ns = S // bs
    qg = q.reshape(B, K, G, hd)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(_dec_kernel, scale=1.0 / math.sqrt(hd),
                               bs=bs, n_kv=ns)
    out = pl.pallas_call(
        kernel,
        grid=(B, K, ns),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, k, s: (b, k, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, k, s: (b, s, k, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, k, s: (b, s, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, k, s: (b, k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qg, k_cache, v_cache)
    return out.reshape(B, Hq, hd)
