"""Jitted wrapper for split-KV decode attention (+ jnp fallback)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("impl", "bs", "interpret"))
def decode_attention(q, k_cache, v_cache, pos, *, impl: str = "pallas",
                     bs: int = 512, interpret: bool = True):
    """q: (B, Hq, hd); caches (B, S, K, hd); pos: scalar current position."""
    if impl == "pallas":
        return decode_attention_pallas(q, k_cache, v_cache, pos, bs=bs,
                                       interpret=interpret)
    return decode_attention_ref(q, k_cache, v_cache, pos)
