"""Pure-jnp oracle for single-token (decode) GQA attention over a KV cache."""
from __future__ import annotations

import math

import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, pos):
    """q: (B, Hq, hd); caches: (B, S, K, hd); slots > pos are masked.
    Returns (B, Hq, hd); math in fp32."""
    B, Hq, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = Hq // K
    qf = q.reshape(B, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,btkh->bkgt", qf, k_cache.astype(jnp.float32))
    s = s / math.sqrt(hd)
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgt,btkh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, hd).astype(q.dtype)
