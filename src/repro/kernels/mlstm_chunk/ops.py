"""Jitted wrapper for the chunkwise mLSTM kernel (+ sequential fallback)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mlstm_chunk.kernel import mlstm_chunk_pallas
from repro.kernels.mlstm_chunk.ref import mlstm_ref


@functools.partial(jax.jit, static_argnames=("impl", "chunk", "interpret"))
def mlstm_chunk(q, k, v, log_i, log_f, *, impl: str = "pallas",
                chunk: int = 128, interpret: bool = True):
    """q,k,v: (B, S, hd); gates (B, S). Returns h (B, S, hd) fp32."""
    if impl == "pallas":
        return mlstm_chunk_pallas(q, k, v, log_i, log_f, chunk=chunk,
                                  interpret=interpret)
    hd = q.shape[-1]
    C0 = jnp.zeros((q.shape[0], hd, hd), jnp.float32)
    n0 = jnp.zeros((q.shape[0], hd), jnp.float32)
    m0 = jnp.full((q.shape[0],), -1e30, jnp.float32)
    h, _ = mlstm_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                     v.astype(jnp.float32), log_i.astype(jnp.float32),
                     log_f.astype(jnp.float32), C0, n0, m0)
    return h
