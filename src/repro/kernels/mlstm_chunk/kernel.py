"""Pallas TPU chunkwise mLSTM kernel (stabilized linear-attention form).

TPU adaptation: the xLSTM CUDA kernels keep per-thread running state in
registers over the sequence; here the (hd x hd) matrix memory lives in VMEM
scratch and is carried across sequence-chunk grid steps (minor-most grid
dim). Within a chunk the quadratic intra-term uses two MXU matmuls
(q k^T and p v) with the log-space gate-decay matrix applied elementwise —
the same math as ``models/xlstm._mlstm_chunk_scan``, validated against the
exact sequential recurrence.

Grid: (B*nh, S/chunk). VMEM per step: q/k/v tiles (C x hd) + decay matrix
(C x C) + state (hd x hd + hd + 1) fp32; with C=128, hd=256 that is ~0.6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, o_ref,
                  C_ref, n_ref, m_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)

    q = q_ref[0].astype(jnp.float32)              # (C, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    li = li_ref[0].astype(jnp.float32)            # (C,)
    lf = lf_ref[0].astype(jnp.float32)

    F = jnp.cumsum(lf)                            # inclusive
    # D[t,s] = F_t - F_s + li_s  (s <= t)
    D = F[:, None] - F[None, :] + li[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >=
           jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    D = jnp.where(tri, D, NEG)

    m_in = m_ref[0, 0]
    m_intra = jnp.max(D, axis=1)                  # (C,)
    m_inter = m_in + F
    m_row = jnp.maximum(m_intra, m_inter)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (C, C)
    w = s * jnp.exp(D - m_row[:, None])
    intra = jax.lax.dot_general(w, v, (((1,), (0,)), ((), ())))
    inter = jnp.exp(m_inter - m_row)[:, None] * \
        jax.lax.dot_general(q, C_ref[...], (((1,), (0,)), ((), ())))
    qn = q @ n_ref[0]
    den = jnp.abs(jnp.sum(w, axis=1) + jnp.exp(m_inter - m_row) * qn)
    den = jnp.maximum(den, jnp.exp(-m_row))
    o_ref[0] = ((intra + inter) / den[:, None]).astype(o_ref.dtype)

    # carry state to the next chunk
    FL = F[-1]
    log_w = FL - F + li                           # (C,)
    m_next = jnp.maximum(m_in + FL, jnp.max(log_w))
    scale_old = jnp.exp(m_in + FL - m_next)
    w_s = jnp.exp(log_w - m_next)                 # (C,)
    C_ref[...] = C_ref[...] * scale_old + \
        jax.lax.dot_general(k * w_s[:, None], v, (((0,), (0,)), ((), ())))
    n_ref[0] = n_ref[0] * scale_old + jnp.sum(k * w_s[:, None], axis=0)
    m_ref[0, 0] = m_next


def mlstm_chunk_pallas(q, k, v, log_i, log_f, *, chunk: int = 128,
                       interpret: bool = True):
    """q,k,v: (B, S, hd) (fold heads into B); gates (B, S).
    Returns h (B, S, hd) fp32. Scaling of k (1/sqrt(hd)) is the caller's."""
    B, S, hd = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    kernel = functools.partial(_mlstm_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, log_i, log_f)
