"""Exact sequential mLSTM recurrence — oracle for the chunkwise kernel.

State per (batch, head): C (hd, hd), n (hd,), m scalar (log-space
stabilizer). Step t:
    m' = max(log_f_t + m, log_i_t)
    C' = exp(log_f_t + m - m') C + exp(log_i_t - m') k_t v_t^T
    n' = exp(log_f_t + m - m') n + exp(log_i_t - m') k_t
    h_t = C'^T q_t / max(|n' . q_t|, exp(-m'))
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlstm_ref(q, k, v, log_i, log_f, C0, n0, m0):
    """q,k,v: (B, S, hd) fp32 (single head; vmap for multi-head);
    log_i, log_f: (B, S). Returns (h (B,S,hd), (C, n, m))."""

    def step(state, xs):
        C, n, m = state
        qt, kt, vt, li, lf = xs
        m_new = jnp.maximum(lf + m, li)
        f_s = jnp.exp(lf + m - m_new)[:, None]
        i_s = jnp.exp(li - m_new)[:, None]
        C = C * f_s[..., None] + i_s[..., None] * \
            jnp.einsum("bd,be->bde", kt, vt)
        n = n * f_s + i_s * kt
        num = jnp.einsum("bd,bde->be", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bd,bd->b", qt, n)),
                          jnp.exp(-m_new))
        return (C, n, m_new), num / den[:, None]

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          log_i.swapaxes(0, 1), log_f.swapaxes(0, 1))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.swapaxes(0, 1), (C, n, m)
