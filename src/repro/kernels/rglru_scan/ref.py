"""Pure-jnp oracle for the RG-LRU linear recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(log_a, x, h0):
    """h_t = exp(log_a_t) * h_{t-1} + x_t.

    log_a, x: (B, S, W); h0: (B, W). Returns h: (B, S, W) in fp32.
    """
    def step(h, inp):
        la, xx = inp
        h = jnp.exp(la) * h + xx
        return h, h

    la = log_a.astype(jnp.float32).swapaxes(0, 1)
    xx = x.astype(jnp.float32).swapaxes(0, 1)
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32), (la, xx))
    return hs.swapaxes(0, 1)
