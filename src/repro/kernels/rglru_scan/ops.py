"""Jitted wrapper for the RG-LRU chunked scan (+ jnp fallback)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.kernels.rglru_scan.ref import rglru_scan_ref


@functools.partial(jax.jit, static_argnames=("impl", "chunk", "bw",
                                             "interpret"))
def rglru_scan(log_a, x, h0, *, impl: str = "pallas", chunk: int = 256,
               bw: int = 128, interpret: bool = True):
    if impl == "pallas":
        return rglru_scan_pallas(log_a, x, h0, chunk=chunk, bw=bw,
                                 interpret=interpret)
    return rglru_scan_ref(log_a, x, h0)
