"""Pallas TPU chunked linear-recurrence kernel for RG-LRU.

TPU adaptation: the GPU implementations of Griffin use a per-thread
sequential scan over registers. On TPU we instead:
  * tile (batch, width) across the outer grid — each (bi, wi) tile is an
    independent recurrence over S;
  * walk sequence chunks on the minor grid dimension; the recurrent carry
    h lives in VMEM scratch across chunk steps;
  * inside a chunk, the scan is computed with a log2(C) associative
    doubling ladder of vector ops (VPU-friendly) rather than C sequential
    steps: (a, b) o (a', b') = (a*a', a'*b + b') composed over strides
    1, 2, 4, ... — numerically identical to the sequential recurrence.

VMEM: a (bw x C) fp32 tile pair plus the (bw,) carry; bw=128 lanes,
C=256 -> ~0.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(log_a_ref, x_ref, h0_ref, o_ref, carry_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        carry_ref[...] = h0_ref[0].astype(jnp.float32)

    la = log_a_ref[0].astype(jnp.float32)       # (C, bw)
    x = x_ref[0].astype(jnp.float32)            # (C, bw)

    # associative doubling ladder over the chunk (axis 0)
    a = la
    b = x
    stride = 1
    while stride < chunk:
        a_shift = jnp.pad(a, ((stride, 0), (0, 0)))[:chunk]
        b_shift = jnp.pad(b, ((stride, 0), (0, 0)))[:chunk]
        mask = (jax.lax.broadcasted_iota(jnp.int32, a.shape, 0) >= stride)
        b = jnp.where(mask, jnp.exp(a) * b_shift + b, b)
        a = jnp.where(mask, a + a_shift, a)
        stride *= 2
    # a = cumulative log decay from chunk start; b = scan with h=0 carry-in
    h = b + jnp.exp(a) * carry_ref[...][None, :]
    o_ref[0] = h.astype(o_ref.dtype)
    carry_ref[...] = h[-1]


def rglru_scan_pallas(log_a, x, h0, *, chunk: int = 256, bw: int = 128,
                      interpret: bool = True):
    """log_a, x: (B, S, W); h0: (B, W). Returns (B, S, W) fp32."""
    B, S, W = log_a.shape
    chunk = min(chunk, S)
    bw = min(bw, W)
    assert S % chunk == 0 and W % bw == 0
    nc, nw = S // chunk, W // bw

    kernel = functools.partial(_rglru_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(B, nw, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, bw), lambda b, w, c: (b, c, w)),
            pl.BlockSpec((1, chunk, bw), lambda b, w, c: (b, c, w)),
            pl.BlockSpec((1, bw), lambda b, w, c: (b, w)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bw), lambda b, w, c: (b, c, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(log_a, x, h0)
    return out
