"""Chameleon-34B — early-fusion VLM: VQ image tokens share the unified 65536
vocab (VQ tokenizer stubbed); QK-norm for stability. [arXiv:2405.09818]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536,
    qk_norm=True, rope_theta=10_000.0, mlp="swiglu",
    source="arXiv:2405.09818 (Chameleon, 34B config)",
)
