from repro.configs.base import (
    ARCH_ALIASES, ARCH_IDS, INPUT_SHAPES, InputShape, ModelConfig,
    all_configs, get_config,
)

__all__ = [
    "ARCH_ALIASES", "ARCH_IDS", "INPUT_SHAPES", "InputShape",
    "ModelConfig", "all_configs", "get_config",
]
