"""Llama-3 405B — dense GQA decoder, 128k vocab. [arXiv:2407.21783]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab_size=128256,
    rope_theta=500_000.0, mlp="swiglu",
    source="arXiv:2407.21783 (The Llama 3 Herd of Models, Table 3)",
)
