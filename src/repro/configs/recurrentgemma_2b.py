"""RecurrentGemma-2B — Griffin-style hybrid: RG-LRU recurrent blocks + local
attention, 1 attention per 2 recurrent layers. [arXiv:2402.19427]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab_size=256000,
    attn_pattern=("rec", "rec", "attn"), window=2048,
    head_dim=256, lru_width=2560, conv_width=4,
    source="arXiv:2402.19427 (Griffin/RecurrentGemma-2B card)",
)
