"""StarCoder2-15B — dense GQA decoder, RoPE, GeLU MLP. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    d_ff=24576, vocab_size=49152,
    rope_theta=100_000.0, mlp="gelu", norm="layernorm", qkv_bias=True,
    source="arXiv:2402.19173 (StarCoder 2, Table 5)",
)
