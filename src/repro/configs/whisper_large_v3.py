"""Whisper large-v3 — encoder-decoder audio backbone; mel+conv frontend is a
stub that supplies precomputed frame embeddings. [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab_size=51866,
    is_encoder_decoder=True, n_encoder_layers=32, encoder_seq=1500,
    mlp="gelu", norm="layernorm", qkv_bias=True, rope_theta=0.0,
    source="arXiv:2212.04356 (Robust Speech Recognition, large-v3 card)",
)
