"""Llama-4 Maverick 400B-A17B — MoE 128 experts top-1 + shared expert,
early-fusion multimodal (VQ tokens share the text vocab; frontend stubbed).
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    n_experts=128, top_k=1, shared_expert=True, moe_every=2, d_ff_dense=16384,
    rope_theta=500_000.0, mlp="swiglu", qk_norm=True,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (family card, per assignment)",
)
