"""Llama-3.1-8B — the paper's exemplar model (RAPID Section 4). [arXiv:2407.21783]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.1-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    rope_theta=500_000.0, mlp="swiglu",
    source="arXiv:2407.21783; RAPID Section 4 exemplar model",
)
