"""xLSTM-350M — sLSTM + mLSTM block stack (no separate FFN; mLSTM blocks carry
an internal 2x up-projection). [arXiv:2405.04517]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    slstm_every=8,   # 7:1 mLSTM:sLSTM ratio per the xLSTM paper
    head_dim=256,
    source="arXiv:2405.04517 (xLSTM, 350M config Table 9)",
)
