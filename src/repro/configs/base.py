"""Config system: model architecture configs + canonical input shapes.

Every assigned architecture gets one module in ``repro/configs`` exporting
``CONFIG`` (the exact published shape, cited) and relying on
``ModelConfig.reduced()`` for the CPU smoke variant.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Optional, Tuple

FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: Optional[int] = None    # sliding-window size (None = full causal)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    shared_expert: bool = False     # llama4-style shared FFN beside routed experts
    moe_group_size: int = 512       # gshard dispatch group size (tokens)
    moe_every: int = 1              # every k-th layer is MoE (llama4: 2)
    d_ff_dense: int = 0             # FFN width of interleaved dense layers (0 -> d_ff)
    # MLP variant
    mlp: str = "swiglu"             # "swiglu" | "gelu"
    # hybrid / ssm structure
    attn_pattern: Tuple[str, ...] = ()   # e.g. ("rec","rec","attn"); repeats over layers
    slstm_every: int = 0            # xlstm: every k-th layer is sLSTM (0 = none)
    conv_width: int = 4             # RG-LRU temporal conv width
    lru_width: int = 0              # RG-LRU recurrence width (0 -> d_model)
    # encoder-decoder (audio)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500         # whisper: 30 s of audio after conv frontend
    # norms / numerics
    norm: str = "rmsnorm"           # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""                # citation for the config

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0 or self.n_kv_heads > self.n_heads is False

    # ---- derived quantities -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind for the decoder stack."""
        if self.family == "ssm":
            kinds = []
            for i in range(self.n_layers):
                if self.slstm_every and (i % self.slstm_every == self.slstm_every - 1):
                    kinds.append("slstm")
                else:
                    kinds.append("mlstm")
            return tuple(kinds)
        if self.family == "hybrid":
            pat = self.attn_pattern or ("rec", "rec", "attn")
            return tuple(pat[i % len(pat)] for i in range(self.n_layers))
        return tuple("attn" for _ in range(self.n_layers))

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        qd, kvd = self.q_dim, self.kv_dim
        total = V * D                      # embed
        if not self.tie_embeddings:
            total += D * V                 # unembed
        enc_layers = self.n_encoder_layers if self.is_encoder_decoder else 0
        kinds = self.layer_kinds()
        ffn_kinds = self.ffn_kinds()
        for kind, fkind in zip(kinds, ffn_kinds):
            total += 2 * D                 # two norms
            if kind == "attn":
                total += D * (qd + 2 * kvd) + qd * D
                if self.qkv_bias:
                    total += qd + 2 * kvd
                total += self._ffn_params(fkind)
            elif kind == "mlstm":
                # xlstm mLSTM block: up-proj 2x, q/k/v proj in inner dim, gates, out
                inner = 2 * D
                total += D * inner * 2 + inner * D           # up (x2 branches) + down
                total += 3 * inner * self.head_dim * self.n_heads // max(self.n_heads, 1)
                total += 2 * inner                           # i/f gate proj (per-unit)
            elif kind == "slstm":
                h = self.n_heads
                total += 4 * D * D + 4 * D * (D // max(h, 1))  # in-proj + block-diag recurrent
                total += self._ffn_params() if F else 0
            elif kind == "rec":
                W = self.lru_width or D
                total += D * W * 2 + W * D                   # in (gate+rec branch) + out
                total += W * self.conv_width + 2 * W * W // 8  # conv + lru gates (8-block diag)
                total += self._ffn_params()
        for _ in range(enc_layers):
            total += 2 * D + D * (qd + 2 * kvd) + qd * D + self._ffn_params()
        if self.is_encoder_decoder:        # cross-attention in every decoder layer
            total += self.n_layers * (D * (qd + 2 * kvd) + qd * D + D)
        return total

    def ffn_kinds(self) -> Tuple[str, ...]:
        """Per-layer FFN kind: "moe" or "dense" (interleaving per moe_every)."""
        if not self.n_experts:
            return tuple("dense" for _ in range(self.n_layers))
        return tuple(
            "moe" if (i % self.moe_every == self.moe_every - 1) else "dense"
            for i in range(self.n_layers)
        )

    def _ffn_params(self, kind: str = "moe") -> int:
        D, F = self.d_model, self.d_ff
        if F == 0:
            return 0
        mult = 3 if self.mlp == "swiglu" else 2
        if self.n_experts and kind == "moe":
            per = mult * D * F
            total = self.n_experts * per + D * self.n_experts  # + router
            if self.shared_expert:
                total += per
            return total
        return mult * D * (self.d_ff_dense or F)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k experts)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        D, F = self.d_model, self.d_ff
        per = (3 if self.mlp == "swiglu" else 2) * D * F
        n_moe_layers = sum(1 for k in self.ffn_kinds() if k == "moe")
        inactive = n_moe_layers * (self.n_experts - self.top_k) * per
        return full - inactive

    # ---- smoke-test variant --------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """2-layer / small-width variant of the same family for CPU smoke tests."""
        changes = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=256,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=64,
            d_ff=512 if self.d_ff else 0,
            vocab_size=512,
            moe_group_size=64,
        )
        if self.n_experts:
            changes["n_experts"] = 4
            changes["top_k"] = min(self.top_k, 2)
        if self.is_encoder_decoder:
            changes["n_encoder_layers"] = 2
            changes["encoder_seq"] = 16
        if self.family == "hybrid":
            changes["attn_pattern"] = ("rec", "attn")
            changes["lru_width"] = 256
            changes["window"] = 32
        if self.family == "ssm":
            changes["slstm_every"] = 2
            changes["n_heads"] = 2
            changes["n_kv_heads"] = 2
            changes["head_dim"] = 128
        return dataclasses.replace(self, **changes)

    def with_window(self, window: int) -> "ModelConfig":
        return dataclasses.replace(self, window=window)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "qwen1_5_4b",
    "granite_3_8b",
    "llama3_405b",
    "starcoder2_15b",
    "llama4_maverick",
    "whisper_large_v3",
    "xlstm_350m",
    "recurrentgemma_2b",
    "phi3_5_moe",
    "chameleon_34b",
)

# CLI-facing aliases (the assignment spells them with dots/dashes)
ARCH_ALIASES = {
    "qwen1.5-4b": "qwen1_5_4b",
    "granite-3-8b": "granite_3_8b",
    "llama3-405b": "llama3_405b",
    "starcoder2-15b": "starcoder2_15b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "whisper-large-v3": "whisper_large_v3",
    "xlstm-350m": "xlstm_350m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "chameleon-34b": "chameleon_34b",
    "llama3.1-8b": "llama31_8b",
}


def get_config(arch: str) -> ModelConfig:
    arch = ARCH_ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
