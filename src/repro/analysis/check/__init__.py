"""simcheck: repo-specific static analysis + runtime invariant sanitizer.

Every headline number this repo produces rests on invariants that used to
be enforced only by convention: power budgets are conserved across
shrink/commit/grow at every hierarchy level, events are causal on the
shared ``EventLoop``, KV for an in-flight request lives on exactly one
live GPU, and the macro planner's float arithmetic exactly mirrors the
per-iteration path. This package machine-checks them, in two coupled
halves:

* **Static half** (``repro.analysis.check.rules``): an AST lint pass with
  repo-specific rule codes RC001-RC007, run as
  ``python -m repro.analysis.check src/``. Violations are reported as
  ``file:line RCnnn severity message``; grandfathered findings live in a
  checked-in baseline (``simcheck-baseline.txt``) where every entry
  carries a justification comment.

* **Runtime half** (``repro.analysis.check.sanitize``): an
  ``InvariantSanitizer`` the simulator core threads through
  ``EventLoop`` / ``PowerManager`` / ``NodeSimulator`` /
  ``ClusterSimulator`` / ``FleetManager`` when ``RAPID_SANITIZE=1`` (or
  ``sanitize=True``). It validates hierarchical power conservation
  (including in-flight budget ops), monotone clock/causality, single
  residency of KV-holding requests, and per-request energy against the
  integrated worst-case node power — at every event dispatch.

The static rules encode the conventions; the sanitizer catches what
static analysis cannot prove. Together they are the correctness
scaffolding that makes aggressive refactors of ``core/`` safe.
"""
from repro.analysis.check.baseline import load_baseline, write_baseline
from repro.analysis.check.rules import Finding, Severity, check_paths, check_source
from repro.analysis.check.sanitize import InvariantSanitizer, sanitize_enabled

__all__ = [
    "Finding",
    "InvariantSanitizer",
    "Severity",
    "check_paths",
    "check_source",
    "load_baseline",
    "sanitize_enabled",
    "write_baseline",
]
