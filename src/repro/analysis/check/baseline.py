"""Baseline (grandfathered-findings) file for the simcheck static pass.

Format — one fingerprint per line, ``#`` comments and blank lines
ignored; every entry is expected to carry a trailing justification
comment explaining why the finding is intentional:

    RC004 repro/core/simulator.py::NodeSimulator.run::_push(t)  # seeds at t>=0 before now advances

Fingerprints are line-number-free (``rule path::qualname::token``) so
they survive unrelated edits; a stale entry (no longer matching any
finding) is reported so the baseline shrinks over time instead of
accreting.
"""
from __future__ import annotations

from pathlib import Path
from typing import Iterable, List, Set, Tuple

from repro.analysis.check.rules import Finding

DEFAULT_BASELINE = "simcheck-baseline.txt"


def load_baseline(path: Path) -> Set[str]:
    """Fingerprints in the baseline file (missing file = empty baseline)."""
    if not path.exists():
        return set()
    entries: Set[str] = set()
    for line in path.read_text().splitlines():
        line = line.split("#", 1)[0].strip()
        if line:
            entries.add(line)
    return entries


def write_baseline(path: Path, findings: Iterable[Finding]) -> int:
    """Write every finding's fingerprint as a fresh baseline. Each entry
    gets a TODO-justify comment — the review gate is that a human replaces
    it with the actual reason the finding is intentional."""
    lines = [
        "# simcheck baseline: grandfathered findings, one fingerprint per",
        "# line. Every entry MUST carry a trailing comment justifying why",
        "# the finding is intentional. Regenerate candidates with",
        "#   python -m repro.analysis.check src/ --update-baseline",
        "# (then justify or fix each entry before committing).",
        "",
    ]
    n = 0
    for f in sorted(set(f.fingerprint for f in findings)):
        lines.append(f"{f}  # TODO: justify or fix")
        n += 1
    path.write_text("\n".join(lines) + "\n")
    return n


def split_by_baseline(findings: List[Finding], baseline: Set[str]) \
        -> Tuple[List[Finding], List[Finding], Set[str]]:
    """(new, suppressed, stale-baseline-entries)."""
    new: List[Finding] = []
    suppressed: List[Finding] = []
    hit: Set[str] = set()
    for f in findings:
        if f.fingerprint in baseline:
            suppressed.append(f)
            hit.add(f.fingerprint)
        else:
            new.append(f)
    return new, suppressed, baseline - hit
