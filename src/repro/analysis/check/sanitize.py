"""Runtime invariant sanitizer for the simulator core (the dynamic half).

Static rules (``repro.analysis.check.rules``) catch conventions broken in
the source; this module catches them broken in *execution* — the regime
arXiv:2506.05508 shows dominates disaggregated-serving fidelity: mid-drain
role flips, facility re-leveling on churn, migrations racing failures.

``InvariantSanitizer`` hooks the shared ``EventLoop`` and validates, at
every event dispatch:

* **Hierarchical power conservation** — ``assert_facility_invariant``
  generalized to every level: per GPU (caps inside the spec envelope,
  or zero when powered off), per node (worst-case draw
  ``sum(max(commanded, effective))`` within the node budget, in-flight
  budget shrinks counted at the old budget), per facility (node budgets
  sum under the facility budget; once a power emergency's shrink is
  enforced, promised budgets also fit the slashed effective limit).
* **Monotone clock / causality** — no event is pushed with a timestamp
  in the past (which would run the shared clock backwards for every
  sibling node), and the dispatch clock never decreases.
* **KV single-residency** — a request lives in at most ONE container
  (prefill queue, in-flight prefill batch, ring wait, in-flight ring
  transfer, decode batch, pending join) across all live nodes; a
  decode-resident request's ``decode_gpu`` matches the GPU that holds
  it; defunct nodes hold nothing. Requests mid-migration live only in
  event payloads (zero residency) — that is the only legal "nowhere".
* **Energy conservation** — total per-request ``energy_j`` charged so
  far never exceeds the integrated worst-case fleet power
  (``sum(max(commanded, effective))`` integrated between dispatches)
  plus the prepay allowance for in-flight prefill batches (their energy
  is charged up front at kick time).
* **Prefix-block single-residency** (``core.prefixcache``) — a cached
  prefix block lives in at most ONE node's cache, or rides exactly one
  in-flight migration as a detached ``carried_block`` — never both; each
  cache's token accounting matches the sum over its entries, fits its
  capacity, and keeps the prefix-closure invariant (every entry's parent
  resident).
* **No silent preemption drops** (``core.tenancy``) — every request a
  priority preemption evicted must terminally resolve: until it finishes
  or is shed it must be resident somewhere, in an event payload
  (requeue/migration in flight), or in the fleet's detection limbo.

Enabling: ``RAPID_SANITIZE=1`` in the environment, or ``sanitize=True``
passed to ``EventLoop`` / ``NodeSimulator`` / ``ClusterSimulator`` /
``FleetManager``. Disabled (the default), the only residue is a
``sanitizer is None`` check per event — the macro-path throughput of
``benchmarks/sim_throughput.py`` is unaffected.

Violations raise ``InvariantViolation`` (an ``AssertionError`` subclass,
so test suites treating invariant failures as assertion failures keep
working) at the exact dispatch where the invariant first broke.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Tuple

EPS_W = 1e-6            # watts tolerance (matches the inline asserts)
EPS_T = 1e-9            # seconds tolerance for causality


class InvariantViolation(AssertionError):
    """A simulator invariant broke at runtime (sanitizer mode)."""


def sanitize_enabled(override: Optional[bool] = None) -> bool:
    """Resolve the sanitizer switch: an explicit ``sanitize=`` argument
    wins; otherwise the ``RAPID_SANITIZE`` environment variable."""
    if override is not None:
        return override
    return os.environ.get("RAPID_SANITIZE", "").lower() in (
        "1", "true", "yes", "on", "full")


class InvariantSanitizer:
    """Event-dispatch-time validator for one ``EventLoop``'s participants.

    Participants register once (``attach_node`` / ``attach_cluster`` /
    ``attach_fleet``); thereafter the loop calls ``check_push`` on every
    schedule and ``after_dispatch`` after every handled event. All state
    is read-only introspection of the registered objects — the sanitizer
    never mutates simulation state, so enabling it cannot change results
    (bit-identity with sanitizer off is part of its own test suite).
    """

    def __init__(self) -> None:
        self.cluster: Optional[Any] = None      # ClusterSimulator
        self.node: Optional[Any] = None         # standalone NodeSimulator
        self.fleet: Optional[Any] = None        # FleetManager
        self.checks = 0                         # dispatches validated
        # worst-case-power integral state (energy conservation)
        self._last_t = 0.0
        self._power_sum_w = 0.0
        self._energy_int_j = 0.0
        # epoch-fence audit: grant_trace entries already validated
        self._grants_seen = 0

    # ---------------- registration ----------------
    def attach_cluster(self, cluster: Any) -> None:
        self.cluster = cluster

    def attach_node(self, node: Any) -> None:
        self.node = node

    def attach_fleet(self, fleet: Any) -> None:
        self.fleet = fleet

    def _nodes(self) -> List[Any]:
        if self.cluster is not None:
            return list(self.cluster.nodes)
        if self.node is not None:
            return [self.node]
        return []

    # ---------------- hook: schedule-time causality ----------------
    def check_push(self, now: float, t: float, kind: str) -> None:
        if t < now - EPS_T:
            raise InvariantViolation(
                f"causality: event {kind!r} pushed at t={t!r} < now={now!r} "
                f"— the shared clock would run backwards")

    # ---------------- hook: dispatch-time validation ----------------
    def after_dispatch(self, loop: Any) -> None:
        now = loop.now
        if now < self._last_t - EPS_T:
            raise InvariantViolation(
                f"clock: dispatch time went backwards "
                f"({self._last_t!r} -> {now!r})")
        # integrate the worst-case draw recorded after the previous event
        # over the elapsed interval (caps only rise AT events, so the
        # recorded post-event sum bounds the draw throughout the interval;
        # in-flight cap lowers are counted at their old, higher value)
        self._energy_int_j += (now - self._last_t) * self._power_sum_w
        self._last_t = now
        nodes = self._nodes()
        self._check_power_hierarchy(nodes)
        resident = self._check_residency(nodes)
        self._check_energy(nodes)
        self._check_epoch_fence()
        self._check_prefix_blocks(nodes, loop)
        self._check_preempted(nodes, loop, resident)
        self._power_sum_w = sum(
            max(c, e)
            for nd in nodes for c, e in zip(nd.pm.commanded, nd.pm.effective))
        self.checks += 1

    # ---------------- invariant: hierarchical power ----------------
    def _check_power_hierarchy(self, nodes: List[Any]) -> None:
        total = 0.0
        for nd in nodes:
            pm = nd.pm
            worst = pm._worst_case()
            if worst > pm.budget + EPS_W:
                raise InvariantViolation(
                    f"power: node {nd.node_id} worst-case draw {worst:.3f} W "
                    f"exceeds its budget {pm.budget:.3f} W")
            if pm._budget_target > pm.budget + EPS_W:
                raise InvariantViolation(
                    f"power: node {nd.node_id} budget target "
                    f"{pm._budget_target:.3f} W above budget "
                    f"{pm.budget:.3f} W (shrink accounting corrupted)")
            if pm.budget > pm.budget_ceil_w + EPS_W:
                raise InvariantViolation(
                    f"power: node {nd.node_id} budget {pm.budget:.3f} W "
                    f"above its GPU-cap ceiling {pm.budget_ceil_w:.3f} W")
            for g in range(pm.n):
                for val, kind in ((pm.commanded[g], "commanded"),
                                  (pm.effective[g], "effective")):
                    if val < -EPS_W or val > pm.max_cap + EPS_W:
                        raise InvariantViolation(
                            f"power: node {nd.node_id} GPU {g} {kind} cap "
                            f"{val:.3f} W outside [0, {pm.max_cap:.0f}] W")
                if pm.powered and pm.commanded[g] < pm.min_cap - EPS_W:
                    raise InvariantViolation(
                        f"power: node {nd.node_id} GPU {g} commanded cap "
                        f"{pm.commanded[g]:.3f} W below the spec floor "
                        f"{pm.min_cap:.0f} W on a powered node")
            total += pm.budget
        if self.cluster is not None \
                and total > self.cluster.facility_budget_w + EPS_W:
            raise InvariantViolation(
                f"power: node budgets sum to {total:.3f} W > facility "
                f"budget {self.cluster.facility_budget_w:.3f} W "
                f"(in-flight shrinks count at their old budgets)")
        # power emergency: once the fleet reports the emergency shrink
        # enforced, the *promised* budgets (in-flight shrinks at their
        # targets) must also fit the slashed effective limit — allowing
        # for node cap floors, which a powered node cannot go below
        if (self.fleet is not None and self.cluster is not None
                and getattr(self.fleet, "_emergency_enforced", False)):
            promised = sum(nd.pm._usable_budget() for nd in nodes
                           if nd.pm.powered)
            floors = sum(nd.pm.budget_floor_w for nd in nodes
                         if nd.pm.powered)
            limit = max(self.cluster.facility_limit_w, floors)
            if promised > limit + EPS_W:
                raise InvariantViolation(
                    f"power: emergency limit "
                    f"{self.cluster.facility_limit_w:.3f} W in force but "
                    f"promised node budgets sum to {promised:.3f} W "
                    f"(floor allowance {floors:.3f} W)")
        # headless window (controller crash): each node locally enforces
        # its last-committed caps, guard-banded — promised budgets
        # (in-flight shrinks at their targets) must still fit under the
        # facility's effective limit with nobody coordinating, because a
        # dead controller cannot be mid-grant
        if (self.cluster is not None
                and getattr(self.cluster, "controller_down", False)):
            promised = sum(nd.pm._usable_budget() for nd in nodes
                           if nd.pm.powered)
            floors = sum(nd.pm.budget_floor_w for nd in nodes
                         if nd.pm.powered)
            limit = max(self.cluster.facility_limit_w, floors)
            if promised > limit + EPS_W:
                raise InvariantViolation(
                    f"power: headless window (controller down) but promised "
                    f"node budgets sum to {promised:.3f} W above the "
                    f"facility limit {self.cluster.facility_limit_w:.3f} W "
                    f"(floor allowance {floors:.3f} W)")

    # ---------------- invariant: KV single-residency ----------------
    def _check_residency(self, nodes: List[Any]) -> Dict[int, Tuple[Any, str]]:
        seen: Dict[int, Tuple[Any, str]] = {}

        def note(req: Any, where: str) -> None:
            prev = seen.get(id(req))
            if prev is not None:
                raise InvariantViolation(
                    f"residency: request rid={req.rid} lives in "
                    f"{prev[1]} AND {where} — KV/queue state must be "
                    f"single-resident")
            seen[id(req)] = (req, where)

        for nd in nodes:
            if nd.defunct:
                if not nd.is_empty():
                    raise InvariantViolation(
                        f"residency: defunct node {nd.node_id} still holds "
                        f"request state")
                continue
            nid = nd.node_id
            for req in nd.q_prefill:
                note(req, f"node{nid}.q_prefill")
            for req in nd.ring_wait:
                note(req, f"node{nid}.ring_wait")
            for req in nd._transfers:
                note(req, f"node{nid}.ring_transfer")
            for gpu in nd.gpus:
                if gpu.inflight_prefill:
                    for req in gpu.inflight_prefill:
                        note(req, f"node{nid}.gpu{gpu.gid}.inflight_prefill")
                for req, _done in gpu.mixed_prefill:
                    note(req, f"node{nid}.gpu{gpu.gid}.mixed_prefill")
                for req in gpu.active:
                    note(req, f"node{nid}.gpu{gpu.gid}.active")
                    self._check_decode_gpu(nd, gpu, req)
                for req in gpu.pending_join:
                    note(req, f"node{nid}.gpu{gpu.gid}.pending_join")
                    self._check_decode_gpu(nd, gpu, req)
        return seen

    @staticmethod
    def _check_decode_gpu(nd: Any, gpu: Any, req: Any) -> None:
        if nd.coalesced or req.decode_gpu is None:
            return
        if req.decode_gpu != gpu.gid:
            raise InvariantViolation(
                f"residency: request rid={req.rid} sits in node "
                f"{nd.node_id} GPU {gpu.gid}'s decode pool but claims "
                f"decode_gpu={req.decode_gpu}")

    # ---------------- invariant: epoch-fenced grants ----------------
    def _check_epoch_fence(self) -> None:
        """No budget grant may commit against a dead controller epoch: a
        ``grant_trace`` entry must carry the current epoch and must not
        land while the controller is down — such grants belong in
        ``fence_trace`` (the source's shrink commits, the watts do not
        move). Incremental read-only scan of the cluster's trace."""
        cl = self.cluster
        if cl is None:
            return
        trace = getattr(cl, "grant_trace", None)
        if trace is None:
            return
        for i in range(self._grants_seen, len(trace)):
            t, src, dst, watts, epoch_issued, epoch_now, down = trace[i]
            if epoch_issued != epoch_now or down:
                raise InvariantViolation(
                    f"epoch fence: budget grant of {watts:.3f} W "
                    f"(node {src} -> node {dst} at t={t:.3f}) committed "
                    f"against epoch {epoch_issued} while the controller is "
                    f"at epoch {epoch_now}"
                    f"{' and DOWN' if down else ''} — grants must not "
                    f"commit across a controller crash")
        self._grants_seen = len(trace)

    # ---------------- invariant: energy conservation ----------------
    def _records(self) -> List[Any]:
        if self.cluster is not None:
            return self.cluster.records
        if self.node is not None:
            return self.node.records
        return []

    def _check_energy(self, nodes: List[Any]) -> None:
        total = 0.0
        for rec in self._records():
            e = rec.energy_j
            if not (e >= 0.0) or e != e or e == float("inf"):
                raise InvariantViolation(
                    f"energy: request rid={rec.rid} carries non-finite or "
                    f"negative energy_j={e!r}")
            total += e
        # prepay allowance: prefill batches are charged in full when the
        # batch is kicked; bound each in-flight batch by max draw over the
        # slowest (min-cap) duration
        prepay = 0.0
        for nd in nodes:
            if nd.defunct:
                continue
            for gpu in nd.gpus:
                if gpu.inflight_prefill:
                    toks = sum(r.rec.input_tokens
                               for r in gpu.inflight_prefill)
                    dt = nd.cost.prefill_time(toks, nd.pm.min_cap)
                    draw = nd.cost.power.draw("prefill", nd.pm.max_cap, True)
                    prepay += draw * dt
        bound = self._energy_int_j + prepay
        if total > bound + 1e-6 + 1e-9 * bound:
            raise InvariantViolation(
                f"energy: charged per-request energy {total:.6f} J exceeds "
                f"the integrated worst-case fleet power {bound:.6f} J "
                f"(integral {self._energy_int_j:.6f} J + prefill prepay "
                f"{prepay:.6f} J)")

    # ------------- invariant: prefix-block single-residency -------------
    @staticmethod
    def _payload_reqs(loop: Any) -> List[Any]:
        """Collect every request riding the event heap: bare ``SimRequest``
        payloads (requeues, transfers), migration tickets (anything with a
        ``.req`` attribute), and tuple/list payloads scanned element-wise.
        Cancelled events are skipped — their payloads will never dispatch."""
        out: List[Any] = []
        cancelled = loop._cancelled

        def scan(p: Any) -> None:
            if p is None:
                return
            if hasattr(p, "rec"):               # a SimRequest
                out.append(p)
            elif hasattr(p, "req"):             # a migration ticket
                scan(p.req)
            elif isinstance(p, (tuple, list)):
                for x in p:
                    scan(x)

        for _t, seq, _kind, _handler, payload in loop.heap:
            if seq in cancelled:
                continue
            scan(payload)
        return out

    def _check_prefix_blocks(self, nodes: List[Any], loop: Any) -> None:
        """Prefix-cache residency: each block lives in at most one node's
        cache or one in-flight ``carried_block`` slot; per-cache token
        accounting and the prefix-closure invariant hold."""
        if not any(getattr(nd, "prefix_cache", None) is not None
                   for nd in nodes):
            return
        blocks: Dict[Any, str] = {}

        def note_block(bid: Any, where: str) -> None:
            prev = blocks.get(bid)
            if prev is not None:
                raise InvariantViolation(
                    f"prefix residency: block {bid} lives in {prev} AND "
                    f"{where} — cached prefixes must be single-resident")
            blocks[bid] = where

        for nd in nodes:
            pc = getattr(nd, "prefix_cache", None)
            if pc is None or nd.defunct:
                continue
            entries = {path: ent for path, ent in pc.entries()}
            tokens = 0
            for path, ent in entries.items():
                note_block(ent.block_id, f"node{nd.node_id}.cache")
                tokens += ent.seg_tokens
                if len(path) > 1 and path[:-1] not in entries:
                    raise InvariantViolation(
                        f"prefix closure: node {nd.node_id} caches "
                        f"{path!r} without its parent {path[:-1]!r}")
            if tokens != pc.used_tokens:
                raise InvariantViolation(
                    f"prefix accounting: node {nd.node_id} cache claims "
                    f"{pc.used_tokens} used tokens but its entries sum to "
                    f"{tokens}")
            if pc.used_tokens > pc.capacity_tokens:
                raise InvariantViolation(
                    f"prefix accounting: node {nd.node_id} cache holds "
                    f"{pc.used_tokens} tokens over its capacity "
                    f"{pc.capacity_tokens}")
        for req in self._payload_reqs(loop):
            blk = getattr(req, "carried_block", None)
            if blk is not None:
                note_block(blk.block_id,
                           f"carried_block(rid={req.rid})")

    # ---------------- invariant: no silent preemption drops -------------
    def _check_preempted(self, nodes: List[Any], loop: Any,
                         resident: Dict[int, Tuple[Any, str]]) -> None:
        """Every request a priority preemption evicted must still be
        reachable until it terminally resolves: resident in some container,
        riding an event payload (requeue or migration in flight), parked in
        the fleet's failure-detection limbo, or finished/shed."""
        victims: set = set()
        for nd in nodes:
            for _t, _rid, _gid, vrids in getattr(nd, "preempt_trace", ()):
                victims.update(vrids)
        if not victims:
            return
        alive = {req.rid for req, _where in resident.values()}
        alive.update(r.rid for r in self._payload_reqs(loop))
        if self.fleet is not None:
            for reqs in self.fleet._limbo.values():
                alive.update(r.rid for r in reqs)
        for rec in self._records():
            if (rec.rid in victims and rec.finish is None
                    and rec.shed_t is None and rec.rid not in alive):
                raise InvariantViolation(
                    f"preemption: evicted request rid={rec.rid} is neither "
                    f"finished, shed, resident, in flight, nor in limbo — "
                    f"silent drop")
