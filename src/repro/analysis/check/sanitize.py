"""Runtime invariant sanitizer for the simulator core (the dynamic half).

Static rules (``repro.analysis.check.rules``) catch conventions broken in
the source; this module catches them broken in *execution* — the regime
arXiv:2506.05508 shows dominates disaggregated-serving fidelity: mid-drain
role flips, facility re-leveling on churn, migrations racing failures.

``InvariantSanitizer`` hooks the shared ``EventLoop`` and validates, at
every event dispatch:

* **Hierarchical power conservation** — ``assert_facility_invariant``
  generalized to every level: per GPU (caps inside the spec envelope,
  or zero when powered off), per node (worst-case draw
  ``sum(max(commanded, effective))`` within the node budget, in-flight
  budget shrinks counted at the old budget), per facility (node budgets
  sum under the facility budget; once a power emergency's shrink is
  enforced, promised budgets also fit the slashed effective limit).
* **Monotone clock / causality** — no event is pushed with a timestamp
  in the past (which would run the shared clock backwards for every
  sibling node), and the dispatch clock never decreases.
* **KV single-residency** — a request lives in at most ONE container
  (prefill queue, in-flight prefill batch, ring wait, in-flight ring
  transfer, decode batch, pending join) across all live nodes; a
  decode-resident request's ``decode_gpu`` matches the GPU that holds
  it; defunct nodes hold nothing. Requests mid-migration live only in
  event payloads (zero residency) — that is the only legal "nowhere".
* **Energy conservation** — total per-request ``energy_j`` charged so
  far never exceeds the integrated worst-case fleet power
  (``sum(max(commanded, effective))`` integrated between dispatches)
  plus the prepay allowance for in-flight prefill batches (their energy
  is charged up front at kick time).

Enabling: ``RAPID_SANITIZE=1`` in the environment, or ``sanitize=True``
passed to ``EventLoop`` / ``NodeSimulator`` / ``ClusterSimulator`` /
``FleetManager``. Disabled (the default), the only residue is a
``sanitizer is None`` check per event — the macro-path throughput of
``benchmarks/sim_throughput.py`` is unaffected.

Violations raise ``InvariantViolation`` (an ``AssertionError`` subclass,
so test suites treating invariant failures as assertion failures keep
working) at the exact dispatch where the invariant first broke.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Tuple

EPS_W = 1e-6            # watts tolerance (matches the inline asserts)
EPS_T = 1e-9            # seconds tolerance for causality


class InvariantViolation(AssertionError):
    """A simulator invariant broke at runtime (sanitizer mode)."""


def sanitize_enabled(override: Optional[bool] = None) -> bool:
    """Resolve the sanitizer switch: an explicit ``sanitize=`` argument
    wins; otherwise the ``RAPID_SANITIZE`` environment variable."""
    if override is not None:
        return override
    return os.environ.get("RAPID_SANITIZE", "").lower() in (
        "1", "true", "yes", "on", "full")


class InvariantSanitizer:
    """Event-dispatch-time validator for one ``EventLoop``'s participants.

    Participants register once (``attach_node`` / ``attach_cluster`` /
    ``attach_fleet``); thereafter the loop calls ``check_push`` on every
    schedule and ``after_dispatch`` after every handled event. All state
    is read-only introspection of the registered objects — the sanitizer
    never mutates simulation state, so enabling it cannot change results
    (bit-identity with sanitizer off is part of its own test suite).
    """

    def __init__(self) -> None:
        self.cluster: Optional[Any] = None      # ClusterSimulator
        self.node: Optional[Any] = None         # standalone NodeSimulator
        self.fleet: Optional[Any] = None        # FleetManager
        self.checks = 0                         # dispatches validated
        # worst-case-power integral state (energy conservation)
        self._last_t = 0.0
        self._power_sum_w = 0.0
        self._energy_int_j = 0.0
        # epoch-fence audit: grant_trace entries already validated
        self._grants_seen = 0

    # ---------------- registration ----------------
    def attach_cluster(self, cluster: Any) -> None:
        self.cluster = cluster

    def attach_node(self, node: Any) -> None:
        self.node = node

    def attach_fleet(self, fleet: Any) -> None:
        self.fleet = fleet

    def _nodes(self) -> List[Any]:
        if self.cluster is not None:
            return list(self.cluster.nodes)
        if self.node is not None:
            return [self.node]
        return []

    # ---------------- hook: schedule-time causality ----------------
    def check_push(self, now: float, t: float, kind: str) -> None:
        if t < now - EPS_T:
            raise InvariantViolation(
                f"causality: event {kind!r} pushed at t={t!r} < now={now!r} "
                f"— the shared clock would run backwards")

    # ---------------- hook: dispatch-time validation ----------------
    def after_dispatch(self, loop: Any) -> None:
        now = loop.now
        if now < self._last_t - EPS_T:
            raise InvariantViolation(
                f"clock: dispatch time went backwards "
                f"({self._last_t!r} -> {now!r})")
        # integrate the worst-case draw recorded after the previous event
        # over the elapsed interval (caps only rise AT events, so the
        # recorded post-event sum bounds the draw throughout the interval;
        # in-flight cap lowers are counted at their old, higher value)
        self._energy_int_j += (now - self._last_t) * self._power_sum_w
        self._last_t = now
        nodes = self._nodes()
        self._check_power_hierarchy(nodes)
        self._check_residency(nodes)
        self._check_energy(nodes)
        self._check_epoch_fence()
        self._power_sum_w = sum(
            max(c, e)
            for nd in nodes for c, e in zip(nd.pm.commanded, nd.pm.effective))
        self.checks += 1

    # ---------------- invariant: hierarchical power ----------------
    def _check_power_hierarchy(self, nodes: List[Any]) -> None:
        total = 0.0
        for nd in nodes:
            pm = nd.pm
            worst = pm._worst_case()
            if worst > pm.budget + EPS_W:
                raise InvariantViolation(
                    f"power: node {nd.node_id} worst-case draw {worst:.3f} W "
                    f"exceeds its budget {pm.budget:.3f} W")
            if pm._budget_target > pm.budget + EPS_W:
                raise InvariantViolation(
                    f"power: node {nd.node_id} budget target "
                    f"{pm._budget_target:.3f} W above budget "
                    f"{pm.budget:.3f} W (shrink accounting corrupted)")
            if pm.budget > pm.budget_ceil_w + EPS_W:
                raise InvariantViolation(
                    f"power: node {nd.node_id} budget {pm.budget:.3f} W "
                    f"above its GPU-cap ceiling {pm.budget_ceil_w:.3f} W")
            for g in range(pm.n):
                for val, kind in ((pm.commanded[g], "commanded"),
                                  (pm.effective[g], "effective")):
                    if val < -EPS_W or val > pm.max_cap + EPS_W:
                        raise InvariantViolation(
                            f"power: node {nd.node_id} GPU {g} {kind} cap "
                            f"{val:.3f} W outside [0, {pm.max_cap:.0f}] W")
                if pm.powered and pm.commanded[g] < pm.min_cap - EPS_W:
                    raise InvariantViolation(
                        f"power: node {nd.node_id} GPU {g} commanded cap "
                        f"{pm.commanded[g]:.3f} W below the spec floor "
                        f"{pm.min_cap:.0f} W on a powered node")
            total += pm.budget
        if self.cluster is not None \
                and total > self.cluster.facility_budget_w + EPS_W:
            raise InvariantViolation(
                f"power: node budgets sum to {total:.3f} W > facility "
                f"budget {self.cluster.facility_budget_w:.3f} W "
                f"(in-flight shrinks count at their old budgets)")
        # power emergency: once the fleet reports the emergency shrink
        # enforced, the *promised* budgets (in-flight shrinks at their
        # targets) must also fit the slashed effective limit — allowing
        # for node cap floors, which a powered node cannot go below
        if (self.fleet is not None and self.cluster is not None
                and getattr(self.fleet, "_emergency_enforced", False)):
            promised = sum(nd.pm._usable_budget() for nd in nodes
                           if nd.pm.powered)
            floors = sum(nd.pm.budget_floor_w for nd in nodes
                         if nd.pm.powered)
            limit = max(self.cluster.facility_limit_w, floors)
            if promised > limit + EPS_W:
                raise InvariantViolation(
                    f"power: emergency limit "
                    f"{self.cluster.facility_limit_w:.3f} W in force but "
                    f"promised node budgets sum to {promised:.3f} W "
                    f"(floor allowance {floors:.3f} W)")
        # headless window (controller crash): each node locally enforces
        # its last-committed caps, guard-banded — promised budgets
        # (in-flight shrinks at their targets) must still fit under the
        # facility's effective limit with nobody coordinating, because a
        # dead controller cannot be mid-grant
        if (self.cluster is not None
                and getattr(self.cluster, "controller_down", False)):
            promised = sum(nd.pm._usable_budget() for nd in nodes
                           if nd.pm.powered)
            floors = sum(nd.pm.budget_floor_w for nd in nodes
                         if nd.pm.powered)
            limit = max(self.cluster.facility_limit_w, floors)
            if promised > limit + EPS_W:
                raise InvariantViolation(
                    f"power: headless window (controller down) but promised "
                    f"node budgets sum to {promised:.3f} W above the "
                    f"facility limit {self.cluster.facility_limit_w:.3f} W "
                    f"(floor allowance {floors:.3f} W)")

    # ---------------- invariant: KV single-residency ----------------
    def _check_residency(self, nodes: List[Any]) -> None:
        seen: Dict[int, Tuple[Any, str]] = {}

        def note(req: Any, where: str) -> None:
            prev = seen.get(id(req))
            if prev is not None:
                raise InvariantViolation(
                    f"residency: request rid={req.rid} lives in "
                    f"{prev[1]} AND {where} — KV/queue state must be "
                    f"single-resident")
            seen[id(req)] = (req, where)

        for nd in nodes:
            if nd.defunct:
                if not nd.is_empty():
                    raise InvariantViolation(
                        f"residency: defunct node {nd.node_id} still holds "
                        f"request state")
                continue
            nid = nd.node_id
            for req in nd.q_prefill:
                note(req, f"node{nid}.q_prefill")
            for req in nd.ring_wait:
                note(req, f"node{nid}.ring_wait")
            for req in nd._transfers:
                note(req, f"node{nid}.ring_transfer")
            for gpu in nd.gpus:
                if gpu.inflight_prefill:
                    for req in gpu.inflight_prefill:
                        note(req, f"node{nid}.gpu{gpu.gid}.inflight_prefill")
                for req, _done in gpu.mixed_prefill:
                    note(req, f"node{nid}.gpu{gpu.gid}.mixed_prefill")
                for req in gpu.active:
                    note(req, f"node{nid}.gpu{gpu.gid}.active")
                    self._check_decode_gpu(nd, gpu, req)
                for req in gpu.pending_join:
                    note(req, f"node{nid}.gpu{gpu.gid}.pending_join")
                    self._check_decode_gpu(nd, gpu, req)

    @staticmethod
    def _check_decode_gpu(nd: Any, gpu: Any, req: Any) -> None:
        if nd.coalesced or req.decode_gpu is None:
            return
        if req.decode_gpu != gpu.gid:
            raise InvariantViolation(
                f"residency: request rid={req.rid} sits in node "
                f"{nd.node_id} GPU {gpu.gid}'s decode pool but claims "
                f"decode_gpu={req.decode_gpu}")

    # ---------------- invariant: epoch-fenced grants ----------------
    def _check_epoch_fence(self) -> None:
        """No budget grant may commit against a dead controller epoch: a
        ``grant_trace`` entry must carry the current epoch and must not
        land while the controller is down — such grants belong in
        ``fence_trace`` (the source's shrink commits, the watts do not
        move). Incremental read-only scan of the cluster's trace."""
        cl = self.cluster
        if cl is None:
            return
        trace = getattr(cl, "grant_trace", None)
        if trace is None:
            return
        for i in range(self._grants_seen, len(trace)):
            t, src, dst, watts, epoch_issued, epoch_now, down = trace[i]
            if epoch_issued != epoch_now or down:
                raise InvariantViolation(
                    f"epoch fence: budget grant of {watts:.3f} W "
                    f"(node {src} -> node {dst} at t={t:.3f}) committed "
                    f"against epoch {epoch_issued} while the controller is "
                    f"at epoch {epoch_now}"
                    f"{' and DOWN' if down else ''} — grants must not "
                    f"commit across a controller crash")
        self._grants_seen = len(trace)

    # ---------------- invariant: energy conservation ----------------
    def _records(self) -> List[Any]:
        if self.cluster is not None:
            return self.cluster.records
        if self.node is not None:
            return self.node.records
        return []

    def _check_energy(self, nodes: List[Any]) -> None:
        total = 0.0
        for rec in self._records():
            e = rec.energy_j
            if not (e >= 0.0) or e != e or e == float("inf"):
                raise InvariantViolation(
                    f"energy: request rid={rec.rid} carries non-finite or "
                    f"negative energy_j={e!r}")
            total += e
        # prepay allowance: prefill batches are charged in full when the
        # batch is kicked; bound each in-flight batch by max draw over the
        # slowest (min-cap) duration
        prepay = 0.0
        for nd in nodes:
            if nd.defunct:
                continue
            for gpu in nd.gpus:
                if gpu.inflight_prefill:
                    toks = sum(r.rec.input_tokens
                               for r in gpu.inflight_prefill)
                    dt = nd.cost.prefill_time(toks, nd.pm.min_cap)
                    draw = nd.cost.power.draw("prefill", nd.pm.max_cap, True)
                    prepay += draw * dt
        bound = self._energy_int_j + prepay
        if total > bound + 1e-6 + 1e-9 * bound:
            raise InvariantViolation(
                f"energy: charged per-request energy {total:.6f} J exceeds "
                f"the integrated worst-case fleet power {bound:.6f} J "
                f"(integral {self._energy_int_j:.6f} J + prefill prepay "
                f"{prepay:.6f} J)")
