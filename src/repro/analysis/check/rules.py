"""AST lint rules for the power-capped simulator core (the static half).

Rule codes (each encodes a convention the simulator's correctness rests
on; see EXPERIMENTS.md "Invariants & static checks"):

RC001  PowerManager budget/cap state may only be written through the
       conservation API. ``budget``/``_budget_target`` writes are legal
       only inside ``shrink_budget``/``commit_budget``/``grow_budget``/
       ``power_on``/``power_off`` (+ ``__init__``); ``commanded``/
       ``effective`` writes only inside ``set_cap``/``tick``/
       ``power_on``/``power_off`` (+ ``__init__``). Everything else —
       a coordinator poking ``node.pm.budget``, a test helper "fixing"
       a cap — silently breaks hierarchical power conservation.

RC002  No wall clock and no unseeded randomness inside ``core/``:
       ``time.time``/``monotonic``/``perf_counter``, ``datetime.now``-
       family calls, bare ``random.*``, and global-state ``np.random.*``
       (anything but ``default_rng``/``Generator``/``SeedSequence``) all
       break the determinism the golden macro-step tests rest on.

RC003  No float ``+=`` accumulation loops over per-iteration quantities
       in ``core/simulator.py``/``core/fleet.py``. Per-iteration times
       and energies must accumulate via the cumsum-as-left-fold idiom
       (``acc[0] = seed; np.cumsum(acc)``, or the matching scalar
       ``x = x + dt`` chain) — that is what keeps ``energy_j`` and every
       timestamp bit-identical between ``fidelity="iter"`` and
       ``"macro"``. A loop-invariant float accumulator written with
       ``+=`` is the tell-tale of a re-derivation that will drift.

RC004  Every ``EventLoop`` post/schedule callsite must pass a time
       ``>= now``. An event pushed into the past makes the shared clock
       run backwards for every sibling node on the loop. The checker
       accepts time expressions that syntactically involve ``now`` (or
       locals derived from ``now`` / the PowerManager time-returning
       API); anything else must be justified in the baseline.

RC005  Public ``core/`` APIs are fully type-annotated (parameters and
       return). The policy-core extraction (ROADMAP item 5) refactors
       against these signatures; unannotated boundaries are where
       refactors silently change types.

RC006  Fault injection in ``core/`` only through the ChaosEngine API.
       Installing a fault hook (``link_fault_fn``) with anything but
       ``None``, or constructing a ``ChaosEngine``, is legal only inside
       ``core/chaos.py`` — ad-hoc failure toggles scattered through the
       core are exactly the unseeded, unreplayable chaos the fig13
       bit-identical-rerun gate exists to prevent. (Benchmarks, examples
       and tests live outside ``core/`` and drive the engine freely.)

RC007  Prefix-cache and tenant-quota state may only be written through
       their public APIs (the same pattern as RC001). ``PrefixCache``'s
       radix/accounting state (``_radix``/``_used_tokens``/``_clock``/
       ``_block_serial``) is legal to write only inside
       ``lookup``/``insert``/``clear``/``pop_leaf``/``adopt``/
       ``_evict_to_fit`` (+ ``__init__``); ``TenantRegistry``'s
       ``_tenants``/``_admitted`` only inside ``register``/
       ``note_admit`` (+ ``__init__``). Anything else — a router
       reaching into a node's cache dict, a benchmark "seeding" quota
       counters — breaks the single-residency and token-accounting
       invariants the runtime sanitizer audits.
"""
from __future__ import annotations

import ast
import dataclasses
import enum
import re
from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Set, Tuple


class Severity(enum.Enum):
    ERROR = "error"
    WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str                 # normalized, repro/... when under a repro tree
    line: int
    col: int
    severity: Severity
    message: str
    token: str                # stable content token for baseline matching
    qualname: str             # enclosing Class.method / function / <module>

    @property
    def fingerprint(self) -> str:
        """Line-number-free identity used by the baseline file."""
        return f"{self.rule} {self.path}::{self.qualname}::{self.token}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.severity.value}] {self.message}")


# --------------------------------------------------------------------------
# RC001 tables: the conservation API of core.power_manager.PowerManager
# --------------------------------------------------------------------------
BUDGET_ATTRS = frozenset({"budget", "_budget_target"})
BUDGET_WRITERS = frozenset({
    "__init__", "shrink_budget", "emergency_shrink", "commit_budget",
    "grow_budget", "power_on", "power_off",
})
CAP_ATTRS = frozenset({"commanded", "effective"})
CAP_WRITERS = frozenset({"__init__", "set_cap", "tick", "power_on",
                         "power_off"})

# RC002 tables
WALLCLOCK_CALLS = frozenset({
    "time.time", "time.monotonic", "time.perf_counter", "time.time_ns",
    "time.monotonic_ns", "time.perf_counter_ns",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today", "date.today",
})
SEEDED_NP_RANDOM = frozenset({"default_rng", "Generator", "SeedSequence",
                              "PCG64", "Philox"})

# RC004: PowerManager methods documented to return an enforcement-ready
# time >= the ``now`` they were called with.
TIME_RETURNING = frozenset({"shift", "shrink_budget", "emergency_shrink",
                            "distribute_uniform", "set_cap"})

# RC006: fault-injection hooks that only core/chaos.py may install (any
# non-None write outside it), plus the engine class itself.
FAULT_HOOK_ATTRS = frozenset({"link_fault_fn", "telemetry_fault_fn"})
CHAOS_CLASSES = frozenset({"ChaosEngine"})

# --------------------------------------------------------------------------
# RC007 tables: the mutation APIs of core.prefixcache.PrefixCache and
# core.tenancy.TenantRegistry (same single-writer pattern as RC001)
# --------------------------------------------------------------------------
PREFIX_ATTRS = frozenset({"_radix", "_used_tokens", "_clock",
                          "_block_serial"})
PREFIX_WRITERS = frozenset({"__init__", "lookup", "insert", "clear",
                            "pop_leaf", "adopt", "_evict_to_fit"})
TENANT_ATTRS = frozenset({"_tenants", "_admitted"})
TENANT_WRITERS = frozenset({"__init__", "register", "note_admit"})

# RC003: names that smell like per-iteration float quantities (times,
# energies, watts). Integer counters (tokens, ctx sums, queue depths) are
# deliberately NOT matched — integer accumulation is exact.
_FLOAT_ACC_RE = re.compile(
    r"(^|_)(t|e|dt|de|ts|time|energy|joule|watt|budget|end|ends)($|_)"
    r"|(_s|_w|_j)$")


def _norm_path(path: Path) -> str:
    """Stable path key: relative to the ``repro`` package root when the
    file lives under one (so baselines survive being run from any cwd or
    absolute path), else the path as given with forward slashes."""
    parts = path.as_posix().split("/")
    for i, part in enumerate(parts):
        if part == "repro" and i + 1 < len(parts):
            return "/".join(parts[i:])
    return path.as_posix()


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _mentions_now(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id == "now":
            return True
        if isinstance(n, ast.Attribute) and n.attr == "now":
            return True
    return False


def _target_names(target: ast.AST) -> Set[str]:
    """Names bound by a for-loop target (tuple targets included)."""
    out: Set[str] = set()
    for n in ast.walk(target):
        if isinstance(n, ast.Name):
            out.add(n.id)
    return out


class _Checker(ast.NodeVisitor):
    def __init__(self, path: Path, source: str):
        self.raw_path = path
        self.path = _norm_path(path)
        self.source = source
        self.findings: List[Finding] = []
        self.class_stack: List[str] = []
        self.func_stack: List[str] = []
        self.loop_targets: List[Set[str]] = []   # one entry per For loop
        self.in_while = 0
        # module import aliases (RC002)
        self.module_aliases: dict = {}           # local name -> module path
        parts = self.path.split("/")
        self.in_core = "core" in parts
        self.in_power_manager = parts[-1] == "power_manager.py"
        self.in_chaos = parts[-1] == "chaos.py"
        self.in_prefixcache = parts[-1] == "prefixcache.py"
        self.in_tenancy = parts[-1] == "tenancy.py"
        self.rc003_scope = (self.in_core
                           and parts[-1] in ("simulator.py", "fleet.py"))

    # ---------------- plumbing ----------------
    @property
    def qualname(self) -> str:
        scope = self.class_stack + self.func_stack
        return ".".join(scope) if scope else "<module>"

    def add(self, rule: str, node: ast.AST, message: str, token: str,
            severity: Severity = Severity.ERROR) -> None:
        self.findings.append(Finding(
            rule=rule, path=self.path, line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0), severity=severity,
            message=message, token=token, qualname=self.qualname))

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.class_stack.append(node.name)
        self.generic_visit(node)
        self.class_stack.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._check_rc005(node)
        self.func_stack.append(node.name)
        self.generic_visit(node)
        self.func_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def visit_For(self, node: ast.For) -> None:
        self.loop_targets.append(_target_names(node.target))
        self.generic_visit(node)
        self.loop_targets.pop()

    def visit_While(self, node: ast.While) -> None:
        self.in_while += 1
        self.generic_visit(node)
        self.in_while -= 1

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self.module_aliases[alias.asname or alias.name.split(".")[0]] = \
                alias.name
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module:
            for alias in node.names:
                self.module_aliases[alias.asname or alias.name] = \
                    f"{node.module}.{alias.name}"
        self.generic_visit(node)

    # ---------------- RC001 ----------------
    def _rc001_target(self, target: ast.AST) -> None:
        # x.budget = / x._budget_target =
        if isinstance(target, ast.Attribute) and target.attr in BUDGET_ATTRS:
            self._rc001_check(target, target.attr, BUDGET_WRITERS)
        elif isinstance(target, ast.Attribute) and target.attr in CAP_ATTRS:
            # rebinding the whole cap list (x.effective = [...])
            self._rc001_check(target, target.attr, CAP_WRITERS)
        elif (isinstance(target, ast.Subscript)
              and isinstance(target.value, ast.Attribute)
              and target.value.attr in CAP_ATTRS):
            # x.commanded[g] = / x.effective[g] =
            self._rc001_check(target, target.value.attr, CAP_WRITERS)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._rc001_target(elt)

    def _rc001_check(self, node: ast.AST, attr: str,
                     writers: frozenset) -> None:
        inside_api = (self.in_power_manager
                      and self.class_stack == ["PowerManager"]
                      and bool(self.func_stack)
                      and self.func_stack[0] in writers)
        if inside_api:
            return
        kind = "budget" if attr in BUDGET_ATTRS else "cap"
        api = sorted(writers - {"__init__"})
        self.add("RC001", node,
                 f"write to PowerManager {kind} state ({attr!r}) outside "
                 f"the conservation API ({', '.join(api)}) — power "
                 f"conservation cannot be audited around it",
                 token=ast.unparse(node))

    # ---------------- RC007 ----------------
    def _rc007_target(self, target: ast.AST) -> None:
        # x._radix = / x._used_tokens += / x._radix[key] = / del x._radix[k]
        if isinstance(target, ast.Attribute) and target.attr in PREFIX_ATTRS:
            self._rc007_check(target, target.attr, "PrefixCache",
                              PREFIX_WRITERS, self.in_prefixcache)
        elif isinstance(target, ast.Attribute) and target.attr in TENANT_ATTRS:
            self._rc007_check(target, target.attr, "TenantRegistry",
                              TENANT_WRITERS, self.in_tenancy)
        elif (isinstance(target, ast.Subscript)
              and isinstance(target.value, ast.Attribute)):
            attr = target.value.attr
            if attr in PREFIX_ATTRS:
                self._rc007_check(target, attr, "PrefixCache",
                                  PREFIX_WRITERS, self.in_prefixcache)
            elif attr in TENANT_ATTRS:
                self._rc007_check(target, attr, "TenantRegistry",
                                  TENANT_WRITERS, self.in_tenancy)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._rc007_target(elt)

    def _rc007_check(self, node: ast.AST, attr: str, cls: str,
                     writers: frozenset, in_file: bool) -> None:
        inside_api = (in_file
                      and self.class_stack == [cls]
                      and bool(self.func_stack)
                      and self.func_stack[0] in writers)
        if inside_api:
            return
        api = sorted(writers - {"__init__"})
        self.add("RC007", node,
                 f"write to {cls} state ({attr!r}) outside its mutation "
                 f"API ({', '.join(api)}) — prefix/tenant accounting "
                 f"invariants cannot be audited around it",
                 token=ast.unparse(node))

    # ---------------- RC002 ----------------
    def _rc002_call(self, node: ast.Call) -> None:
        if not self.in_core:
            return
        dotted = _dotted(node.func)
        if dotted is None:
            return
        root = dotted.split(".")[0]
        resolved = self.module_aliases.get(root)
        # normalize numpy aliases: np.random.X -> numpy.random.X
        if resolved == "numpy" or root in ("numpy", "np"):
            rest = dotted.split(".")[1:]
            if len(rest) >= 2 and rest[0] == "random" \
                    and rest[1] not in SEEDED_NP_RANDOM:
                self.add("RC002", node,
                         f"unseeded global-state numpy randomness "
                         f"({dotted}) in core/ — breaks the determinism "
                         f"the golden macro-step tests rest on; use "
                         f"np.random.default_rng(seed)",
                         token=dotted)
            return
        if resolved == "random" or root == "random":
            if "." in dotted:
                self.add("RC002", node,
                         f"bare random.* call ({dotted}) in core/ — "
                         f"unseeded global randomness; use "
                         f"np.random.default_rng(seed)",
                         token=dotted)
            return
        if dotted in WALLCLOCK_CALLS or (
                resolved and any(dotted.replace(root, resolved, 1) == w
                                 for w in WALLCLOCK_CALLS)):
            self.add("RC002", node,
                     f"wall-clock read ({dotted}) in core/ — simulated "
                     f"time must come from the EventLoop clock",
                     token=dotted)

    # ---------------- RC003 ----------------
    def _rc003(self, node: ast.AugAssign) -> None:
        if not self.rc003_scope or not isinstance(node.op, ast.Add):
            return
        if not self.loop_targets and not self.in_while:
            return
        target = node.target
        # the accumulated-into name: last attribute component or bare name
        if isinstance(target, ast.Attribute):
            name = target.attr
        elif isinstance(target, ast.Name):
            name = target.id
        else:
            return
        if not _FLOAT_ACC_RE.search(name):
            return
        # a target that depends on the innermost for-loop variables is a
        # per-item write (one += per request), not an accumulation across
        # iterations — exempt
        loop_vars: Set[str] = set()
        for tv in self.loop_targets:
            loop_vars |= tv
        if loop_vars & _names_in(target):
            return
        self.add("RC003", node,
                 f"float '+=' accumulation of per-iteration quantity "
                 f"{name!r} inside a loop — use the cumsum-as-left-fold "
                 f"idiom (seeded np.cumsum, or the scalar 'x = x + dt' "
                 f"chain mirroring it) so iter/macro stay bit-identical",
                 token=ast.unparse(node))

    # ---------------- RC004 ----------------
    def _rc004_call(self, node: ast.Call, fn_node: Optional[ast.AST]) -> None:
        func = node.func
        if not isinstance(func, ast.Attribute):
            return
        dotted = _dotted(func) or ""
        is_loop_push = func.attr == "push" and "loop" in dotted.split(".")
        is_node_push = func.attr == "_push"
        if not (is_loop_push or is_node_push) or not node.args:
            return
        t_arg = node.args[0]
        if self._time_safe(t_arg, fn_node):
            return
        self.add("RC004", node,
                 f"event scheduled with time {ast.unparse(t_arg)!r} not "
                 f"provably >= now — an event pushed into the past runs "
                 f"the shared clock backwards for every node on the loop",
                 token=f"{func.attr}({ast.unparse(t_arg)})")

    def _time_safe(self, expr: ast.AST, fn_node: Optional[ast.AST],
                   seen: Optional[Set[str]] = None) -> bool:
        if _mentions_now(expr):
            return True
        if isinstance(expr, ast.Call):
            dotted = _dotted(expr.func) or ""
            if dotted == "max":
                return any(self._time_safe(a, fn_node, seen)
                           for a in expr.args)
            if dotted.split(".")[-1] in TIME_RETURNING:
                return True
            if dotted in ("float", "int") and expr.args:
                return self._time_safe(expr.args[0], fn_node, seen)
        if isinstance(expr, ast.Name) and fn_node is not None:
            seen = seen or set()
            if expr.id in seen:
                return True          # self-referential update (t = max(t, x))
            seen.add(expr.id)
            assigns = self._local_assigns(fn_node, expr.id)
            if assigns:
                return all(self._time_safe(a, fn_node, seen)
                           for a in assigns)
        return False

    @staticmethod
    def _local_assigns(fn_node: ast.AST, name: str) -> List[ast.AST]:
        """RHS expressions assigned to ``name`` in this function body
        (tuple unpacking maps the whole RHS to every unpacked name — a
        call to a time-returning API covers all its outputs)."""
        out: List[ast.AST] = []
        for n in ast.walk(fn_node):
            if isinstance(n, ast.Assign):
                for tgt in n.targets:
                    if isinstance(tgt, ast.Name) and tgt.id == name:
                        out.append(n.value)
                    elif isinstance(tgt, (ast.Tuple, ast.List)):
                        names = [e.id for e in tgt.elts
                                 if isinstance(e, ast.Name)]
                        if name in names:
                            if isinstance(n.value, (ast.Tuple, ast.List)) \
                                    and len(n.value.elts) == len(tgt.elts):
                                out.append(
                                    n.value.elts[names.index(name)]
                                    if len(names) == len(tgt.elts)
                                    else n.value)
                            else:
                                out.append(n.value)
            elif isinstance(n, ast.AugAssign):
                if isinstance(n.target, ast.Name) and n.target.id == name:
                    out.append(n.value)
        return out

    # ---------------- RC006 ----------------
    def _rc006_assign(self, node: ast.AST, value: Optional[ast.AST]) -> None:
        """Flag non-None writes to fault-injection hooks outside chaos.py
        (``x.link_fault_fn = None`` — declaring/clearing the hook — is the
        legal idiom everywhere)."""
        if not self.in_core or self.in_chaos:
            return
        if not (isinstance(node, ast.Attribute)
                and node.attr in FAULT_HOOK_ATTRS):
            return
        if value is None or (isinstance(value, ast.Constant)
                             and value.value is None):
            return      # bare declaration / clearing the hook
        self.add("RC006", node,
                 f"fault-injection hook {node.attr!r} installed outside "
                 f"core/chaos.py — fault injection in core/ must go "
                 f"through the ChaosEngine API so chaos schedules stay "
                 f"seeded and replayable",
                 token=ast.unparse(node))

    def _rc006_call(self, node: ast.Call) -> None:
        if not self.in_core or self.in_chaos:
            return
        dotted = _dotted(node.func) or ""
        if dotted.split(".")[-1] in CHAOS_CLASSES:
            self.add("RC006", node,
                     f"{dotted} constructed inside core/ (outside chaos.py) "
                     f"— the simulator core must stay fault-free unless a "
                     f"caller wires a ChaosEngine in from outside",
                     token=dotted)

    # ---------------- RC005 ----------------
    def _check_rc005(self, node: ast.FunctionDef) -> None:
        if not self.in_core:
            return
        if self.func_stack:
            return                    # nested def: not API surface
        name = node.name
        public = not name.startswith("_") or name == "__init__"
        if not public:
            return
        if self.class_stack and self.class_stack[0].startswith("_"):
            return                    # private class
        args = node.args
        missing: List[str] = []
        positional = args.posonlyargs + args.args
        skip_first = bool(self.class_stack) and not any(
            isinstance(d, ast.Name) and d.id == "staticmethod"
            for d in node.decorator_list)
        for i, a in enumerate(positional):
            if skip_first and i == 0:
                continue              # self / cls
            if a.annotation is None:
                missing.append(a.arg)
        for a in args.kwonlyargs:
            if a.annotation is None:
                missing.append(a.arg)
        if args.vararg is not None and args.vararg.annotation is None:
            missing.append("*" + args.vararg.arg)
        if args.kwarg is not None and args.kwarg.annotation is None:
            missing.append("**" + args.kwarg.arg)
        needs_return = name != "__init__" and node.returns is None
        if not missing and not needs_return:
            return
        what = []
        if missing:
            what.append(f"parameters {', '.join(missing)}")
        if needs_return:
            what.append("return type")
        self.add("RC005", node,
                 f"public core/ API {self.qualname + '.' if self.class_stack else ''}"
                 f"{name} missing annotations: {'; '.join(what)}",
                 token=f"def {name}")

    # ---------------- dispatch ----------------
    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            self._rc001_target(tgt)
            self._rc007_target(tgt)
            self._rc006_assign(tgt, node.value)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self._rc001_target(node.target)
        self._rc007_target(node.target)
        self._rc006_assign(node.target, node.value)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._rc001_target(node.target)
        self._rc007_target(node.target)
        self._rc003(node)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for tgt in node.targets:
            self._rc007_target(tgt)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        self._rc002_call(node)
        self._rc006_call(node)
        self.generic_visit(node)


def check_source(source: str, path: Path) -> List[Finding]:
    """Run every rule over one file's source; returns findings."""
    tree = ast.parse(source, filename=str(path))
    checker = _Checker(path, source)
    checker.visit(tree)
    # RC004 needs the enclosing function for local dataflow: do a second
    # pass that walks functions and their calls together.
    _rc004_pass(tree, checker)
    checker.findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return checker.findings


def _rc004_pass(tree: ast.Module, checker: _Checker) -> None:
    def walk(node: ast.AST, fn: Optional[ast.AST],
             cls_stack: List[str], fn_stack: List[str]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                walk(child, fn, cls_stack + [child.name], fn_stack)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                walk(child, child, cls_stack, fn_stack + [child.name])
            else:
                if isinstance(child, ast.Call):
                    checker.class_stack = cls_stack
                    checker.func_stack = fn_stack
                    checker._rc004_call(child, fn)
                walk(child, fn, cls_stack, fn_stack)
    walk(tree, None, [], [])
    checker.class_stack = []
    checker.func_stack = []


def iter_py_files(paths: Sequence[str]) -> Iterable[Path]:
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from sorted(path.rglob("*.py"))
        elif path.suffix == ".py":
            yield path


def check_paths(paths: Sequence[str]) -> Tuple[List[Finding], int]:
    """Check every .py file under ``paths``; returns (findings, n_files)."""
    findings: List[Finding] = []
    n = 0
    for path in iter_py_files(paths):
        n += 1
        findings.extend(check_source(path.read_text(), path))
    return findings, n
