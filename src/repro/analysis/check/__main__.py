"""simcheck CLI: ``python -m repro.analysis.check src/``.

Exit status: 0 when every finding is either absent or suppressed by the
baseline; 1 when new findings exist (CI fails on new findings only, so
the baseline is the explicit, reviewable debt list).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis.check.baseline import (DEFAULT_BASELINE, load_baseline,
                                           split_by_baseline, write_baseline)
from repro.analysis.check.rules import check_paths


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="Repo-specific static analysis for the power-capped "
                    "simulator core (rules RC001-RC005).")
    ap.add_argument("paths", nargs="+", help="files or directories to check")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline file (default: {DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current findings "
                         "(entries still need human justification)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress the summary line")
    args = ap.parse_args(argv)

    findings, n_files = check_paths(args.paths)
    baseline_path = Path(args.baseline)

    if args.update_baseline:
        n = write_baseline(baseline_path, findings)
        print(f"simcheck: wrote {n} baseline entries to {baseline_path}")
        return 0

    baseline = set() if args.no_baseline else load_baseline(baseline_path)
    new, suppressed, stale = split_by_baseline(findings, baseline)

    for f in new:
        print(f.render())
    for fp in sorted(stale):
        print(f"simcheck: stale baseline entry (fix landed? delete it): {fp}")
    if not args.quiet:
        print(f"simcheck: {n_files} files, {len(new)} new finding(s), "
              f"{len(suppressed)} baselined, {len(stale)} stale baseline "
              f"entr{'y' if len(stale) == 1 else 'ies'}")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
