"""simcheck CLI: ``python -m repro.analysis.check src/``.

Exit status: 0 when every finding is either absent or suppressed by the
baseline; 1 when new findings exist OR the baseline carries stale
entries (debt that no longer exists must be deleted, or the baseline
rots into a list nobody trusts). ``--allow-stale`` downgrades stale
entries back to warnings for mid-refactor runs.

``--docstrings`` switches to a documentation-coverage gate (the prose
sibling of RC005's annotation rule): every public module, class,
function, and method in the given files must carry a docstring. No
baseline applies — the gated surfaces (e.g. ``core/autoscale.py``) are
expected to be fully documented.
"""
from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path
from typing import Iterator, List, Optional

from repro.analysis.check.baseline import (DEFAULT_BASELINE, load_baseline,
                                           split_by_baseline, write_baseline)
from repro.analysis.check.rules import check_paths


def _iter_py(paths: List[str]) -> Iterator[Path]:
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from sorted(path.rglob("*.py"))
        else:
            yield path


def check_docstrings(paths: List[str]) -> int:
    """Docstring-coverage gate; returns the number of missing docstrings.

    Public surface = the module itself, plus every top-level class /
    function / method whose name (and enclosing class) is not
    underscore-prefixed. ``__init__`` is exempt — the class docstring
    covers construction.
    """
    missing: List[str] = []
    n_public = 0
    for path in _iter_py(paths):
        tree = ast.parse(path.read_text(), filename=str(path))
        n_public += 1
        if not ast.get_docstring(tree):
            missing.append(f"{path}:1: module docstring missing")
        scopes = [(tree, "")]
        while scopes:
            node, prefix = scopes.pop()
            for child in ast.iter_child_nodes(node):
                if not isinstance(child, (ast.ClassDef, ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                    continue
                if child.name.startswith("_"):
                    continue
                n_public += 1
                qual = f"{prefix}{child.name}"
                if not ast.get_docstring(child):
                    missing.append(f"{path}:{child.lineno}: public "
                                   f"{'class' if isinstance(child, ast.ClassDef) else 'function'} "
                                   f"`{qual}` has no docstring")
                if isinstance(child, ast.ClassDef):
                    scopes.append((child, f"{qual}."))
    for line in missing:
        print(line)
    print(f"simcheck --docstrings: {n_public} public surfaces, "
          f"{len(missing)} undocumented")
    return len(missing)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="Repo-specific static analysis for the power-capped "
                    "simulator core (rules RC001-RC007).")
    ap.add_argument("paths", nargs="+", help="files or directories to check")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline file (default: {DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current findings "
                         "(entries still need human justification)")
    ap.add_argument("--allow-stale", action="store_true",
                    help="stale baseline entries warn instead of failing "
                         "(escape hatch for mid-refactor runs)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress the summary line")
    ap.add_argument("--docstrings", action="store_true",
                    help="documentation-coverage gate: require docstrings "
                         "on the public API of the given files (no baseline)")
    args = ap.parse_args(argv)

    if args.docstrings:
        return 1 if check_docstrings(args.paths) else 0

    findings, n_files = check_paths(args.paths)
    baseline_path = Path(args.baseline)

    if args.update_baseline:
        n = write_baseline(baseline_path, findings)
        print(f"simcheck: wrote {n} baseline entries to {baseline_path}")
        return 0

    baseline = set() if args.no_baseline else load_baseline(baseline_path)
    new, suppressed, stale = split_by_baseline(findings, baseline)

    for f in new:
        print(f.render())
    for fp in sorted(stale):
        print(f"simcheck: stale baseline entry (fix landed? delete it): {fp}")
    if not args.quiet:
        print(f"simcheck: {n_files} files, {len(new)} new finding(s), "
              f"{len(suppressed)} baselined, {len(stale)} stale baseline "
              f"entr{'y' if len(stale) == 1 else 'ies'}")
    return 1 if new or (stale and not args.allow_stale) else 0


if __name__ == "__main__":
    sys.exit(main())
