"""HLO-text analysis: collective-traffic accounting + roofline terms.

The dry-run compiles a per-device SPMD module; ``cost_analysis`` gives
per-device FLOPs/bytes, and the HLO text gives per-device collective
operand/result sizes. Roofline terms are therefore per-chip seconds.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\/ ]+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective type (result-shape proxy).

    ``-done`` ops are skipped so async start/done pairs count once.
    """
    out: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    counts: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        if "-done" in m.group(0):
            continue
        out[op] += _shape_bytes(shape_str)
        counts[op] += 1
    out["_counts"] = counts
    return out


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu_v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link


TPU_V5E = Hardware()


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float                       # per-device HLO flops
    bytes_accessed: float              # per-device HLO bytes
    coll_bytes: float                  # per-device collective bytes
    model_flops: float                 # analytic 6*N*D (global)
    useful_ratio: float                # model_flops / (flops * chips)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)


def roofline_terms(cost: dict, coll: Dict[str, int], n_chips: int,
                   model_flops: float, hw: Hardware = TPU_V5E) -> Roofline:
    flops = float(cost.get("flops", 0.0) or 0.0)
    nbytes = float(cost.get("bytes accessed", 0.0) or 0.0)
    cb = float(sum(v for k, v in coll.items() if not k.startswith("_")))
    return Roofline(
        compute_s=flops / hw.peak_flops,
        memory_s=nbytes / hw.hbm_bw,
        collective_s=cb / hw.ici_bw,
        flops=flops, bytes_accessed=nbytes, coll_bytes=cb,
        model_flops=model_flops,
        useful_ratio=model_flops / max(flops * n_chips, 1.0),
    )


def train_model_flops(cfg, tokens: int) -> float:
    """6*N*D with N = active params (fwd+bwd)."""
    return 6.0 * cfg.active_param_count() * tokens


def step_model_flops(cfg, shape) -> float:
    if shape.kind == "train":
        return train_model_flops(cfg, shape.global_batch * shape.seq_len)
    if shape.kind == "prefill":
        return 2.0 * cfg.active_param_count() * shape.global_batch * shape.seq_len
    return 2.0 * cfg.active_param_count() * shape.global_batch   # one token
