"""HLO-text graph analysis with while-loop trip-count multiplication.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so a
126-layer scanned transformer under-reports FLOPs and collective bytes by
~126x. This parser rebuilds the computation graph from ``as_text()``:

  * dot FLOPs per computation (2 * prod(result) * contraction size),
  * convolution FLOPs (approximated from operand/result shapes),
  * collective result-bytes per computation,

then walks call/while/conditional/fusion edges multiplying by loop trip
counts (extracted from the loop condition's ``compare(..., constant)``).

This gives trip-corrected per-device compute and collective numbers for the
roofline; XLA's own single-trip numbers are reported alongside for reference.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _strip_async_suffix(opcode: str) -> str:
    """Remove an async ``-start``/``-done`` *suffix* (``str.rstrip`` strips a
    character set and would mangle e.g. ``all-to-all`` -> ``all-to-all`` ok
    but ``broadcast`` -> ``broadca``)."""
    for suf in ("-start", "-done"):
        if opcode.endswith(suf):
            return opcode[: -len(suf)]
    return opcode

_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLEE = re.compile(r"(?:to_apply|body|condition|branch_computations|"
                     r"true_computation|false_computation|called_computations)="
                     r"(?:{([^}]*)}|%?([\w.\-]+))")
_CONST = re.compile(r"constant\((-?\d+)\)")
_DIMS = re.compile(r"lhs_contracting_dims={([\d,]*)}")
_BATCH_DIMS = re.compile(r"lhs_batch_dims={([\d,]*)}")


def _shape_dims(shape_str: str):
    m = _SHAPE.search(shape_str)
    if not m:
        return None, []
    dt = m.group(1)
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return dt, dims


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    opcode: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op] = dataclasses.field(default_factory=list)
    dot_flops: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def shape_of(self, operand: str) -> Optional[str]:
        for op in self.ops:
            if op.name == operand:
                return op.shape
        return None


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line.strip()) if "{" in line and "->" in line else None
        if hdr and "=" not in line.split("(")[0]:
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            cur.ops.append(Op(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps


def _param_shapes(comp: Computation) -> Dict[str, str]:
    return {op.name: op.shape for op in comp.ops if op.opcode == "parameter"}


def _args_of(rest: str) -> str:
    """Operand list of ``opcode(<args>)...``: everything up to the paren that
    closes the call (TPU layouts like ``{1,0:T(8,128)}`` nest parens)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i]
    return rest


def _split_top(args: str) -> List[str]:
    """Split on commas at bracket depth 0 (shapes carry ``[4,64]{1,0}``)."""
    parts, cur, depth = [], [], 0
    for ch in args:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


def _parse_operands(rest: str) -> List[tuple]:
    """Operands of an op line as ``(name, inline_shape_or_None)``. Full-form
    HLO prints each operand as ``dtype[dims]{layout} %name``; short form is
    just ``%name`` (or a bare identifier)."""
    out = []
    for part in _split_top(_args_of(rest)):
        toks = part.split()
        name = toks[-1].lstrip("%")
        shape = part[: -len(toks[-1])].strip() if len(toks) > 1 else None
        out.append((name, shape or None))
    return out


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups={{([\d,]+)}")


def _group_size(rest: str) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 16


def _wire_factor(base: str, n: int) -> float:
    """Ring-algorithm bytes-on-wire per device, relative to the op's result
    bytes: all-reduce 2(n-1)/n of the (full) result; all-gather (n-1)/n of
    the gathered result; reduce-scatter sends (n-1) shards (result = shard);
    all-to-all (n-1)/n; collective-permute 1."""
    if base == "all-reduce":
        return 2.0 * (n - 1) / n
    if base == "all-gather":
        return (n - 1) / n
    if base == "reduce-scatter":
        return float(n - 1)
    if base == "all-to-all":
        return (n - 1) / n
    return 1.0


def _analyze_local(comp: Computation):
    flops = 0.0
    coll: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for op in comp.ops:
        if op.opcode == "dot":
            _, rdims = _shape_dims(op.shape)
            rsize = 1
            for d in rdims:
                rsize *= d
            # contraction size from lhs operand shape + contracting dims;
            # an inline operand shape (full-form dump) is authoritative,
            # else fall back to the defining op inside this computation
            mC = _DIMS.search(op.rest)
            operands = _parse_operands(op.rest)
            csize = 1
            if mC and operands:
                lhs_name, lhs_inline = operands[0]
                lhs_shape = lhs_inline or comp.shape_of(lhs_name)
                if lhs_shape:
                    _, ldims = _shape_dims(lhs_shape)
                    for ci in (int(x) for x in mC.group(1).split(",") if x):
                        if ci < len(ldims):
                            csize *= ldims[ci]
            flops += 2.0 * rsize * csize
        elif (base := _strip_async_suffix(op.opcode)) in COLLECTIVES:
            if op.opcode.endswith("-done"):
                continue
            b = _shape_bytes(op.shape) * _wire_factor(base,
                                                      _group_size(op.rest))
            coll[base] = coll.get(base, 0.0) + b
            counts[base] = counts.get(base, 0) + 1
    comp.dot_flops = flops
    comp.coll_bytes = coll
    comp.coll_counts = counts


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    const_vals = []
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.match(r"(-?\d+)\)", op.rest)
            if m:
                const_vals.append(int(m.group(1)))
    vals = [v for v in const_vals if v > 0]
    return max(vals) if vals else 1


@dataclasses.dataclass
class ModuleCost:
    dot_flops: float
    coll_bytes: Dict[str, float]
    coll_counts: Dict[str, int]
    loops: List[tuple]

    @property
    def total_coll_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))


def analyze(text: str, entry: Optional[str] = None) -> ModuleCost:
    comps = parse_module(text)
    for c in comps.values():
        _analyze_local(c)

    callees: Dict[str, List[tuple]] = {}   # comp -> [(callee, mult)]
    loops = []
    attr_re = re.compile(
        r"(?:body|condition|calls|to_apply|true_computation|"
        r"false_computation)=%?([\w.\-]+)")
    branches_re = re.compile(r"branch_computations={([^}]*)}")
    trip_re = re.compile(r'"known_trip_count":\s*{"n":\s*"(\d+)"')
    for c in comps.values():
        edges = []
        for op in c.ops:
            names = attr_re.findall(op.rest)
            for m in branches_re.finditer(op.rest):
                names.extend(re.findall(r"%?([\w.\-]+)", m.group(1)))
            if not names:
                continue
            if op.opcode == "while":
                mt = trip_re.search(op.rest)
                if mt:
                    trip = int(mt.group(1))
                else:
                    cond_m = re.search(r"condition=%?([\w.\-]+)", op.rest)
                    trip = _trip_count(comps, cond_m.group(1)) if cond_m else 1
                loops.append((op.name, trip))
                for n in names:
                    if n in comps:
                        edges.append((n, trip))
            else:
                for n in names:
                    if n in comps:
                        edges.append((n, 1))
        callees[c.name] = edges

    # entry = computation not called by anyone, or explicit
    called = {n for edges in callees.values() for n, _ in edges}
    roots = [n for n in comps if n not in called]
    if entry is None:
        entry = roots[0] if roots else next(iter(comps))

    total_flops = 0.0
    total_coll: Dict[str, float] = {}
    total_counts: Dict[str, int] = {}
    seen_stack = set()

    def walk(name: str, mult: float):
        nonlocal total_flops
        if name in seen_stack or name not in comps:   # cycle guard
            return
        seen_stack.add(name)
        c = comps[name]
        total_flops += mult * c.dot_flops
        for k, v in c.coll_bytes.items():
            total_coll[k] = total_coll.get(k, 0.0) + mult * v
        for k, v in c.coll_counts.items():
            total_counts[k] = total_counts.get(k, 0) + int(mult) * v
        for callee, m in callees.get(name, ()):  # noqa: B020
            walk(callee, mult * m)
        seen_stack.discard(name)

    walk(entry, 1.0)
    return ModuleCost(total_flops, total_coll, total_counts, loops)
