"""Analytical step-time model for disaggregated serving.

Gives prefill / decode / KV-transfer times for a (model, hardware, power)
triple. Prefill is compute-bound (scales with the power curve); decode is
HBM-bound (scales weakly, saturating by ~600 W) — the asymmetry RAPID
exploits. Constants for MI300X reproduce the paper's setting; TPU v5e
constants are provided for the target hardware.

Calibration sanity (Llama-3.1-8B, MI300X, 750 W): prefill 8k tokens
~ 2*8e9*8192 / (1307e12 * 0.5) = 0.20 s; decode step at batch 32 reads
16 GB weights + KV => ~4-6 ms/token. Both line up with the paper's SLO
regime (TTFT 1 s, TPOT 25-40 ms).

The step-time functions sit on the simulator's hottest path (one call per
decode iteration per GPU), so derived sizes (``weight_bytes``,
``kv_bytes_per_token``) are computed once per ``CostModel`` and the
time/power functions are memoized. The memo keys use the *exact* call
arguments — callers quantize naturally (caps only change at controller
decisions, prefill batches repeat the token-budget sizes), so memoization
changes nothing numerically: a hit returns the identical float the formula
would produce.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.core.power_model import PowerModel

# Safety valve for the exact-key memo dicts: decode ctx drifts by one token
# per iteration so very long runs could accrue many keys; reset when huge.
_MEMO_MAX = 1 << 18


@dataclasses.dataclass(frozen=True)
class GPUSpec:
    name: str
    peak_flops: float            # bf16, dense
    hbm_bw: float                # bytes/s
    hbm_bytes: float
    link_bw: float               # intra-node per-pair (XGMI / ICI / NVLink)
    # cross-node interconnect available to ONE migration stream (RDMA NIC
    # share, e.g. one 400 GbE port): sets the cost of moving a live
    # request's KV cache to another node (``core.fleet`` migration engine)
    node_link_bw: float = 50e9
    # serving-efficiency calibration (vLLM-style single-GPU TP=1 serving,
    # includes scheduler/launch inefficiency; see EXPERIMENTS.md §Calibration)
    # Serving MFU is modeled flat in batch tokens: co-batching keeps small
    # work efficient while long prompts' quadratic attention cost (omitted
    # by the 2*N*D flops term) cancels their matmul gains. The constant is
    # the Fig-5 calibration anchor, measured at n = 4096.
    mfu_prefill: float = 0.125
    mbu_decode: float = 0.34
    overhead_prefill_s: float = 0.03   # per prefill batch
    overhead_decode_s: float = 0.006   # per decode iteration
    max_active_decode: int = 64        # vLLM max_num_seqs-style cap
    # power envelope: cap range the vendor tool accepts, and the name of the
    # calibrated PowerCurve set (``core.power_model.get_power_model``) —
    # heterogeneous clusters resolve per-node curves from the node's spec
    min_cap_w: float = 400.0
    max_cap_w: float = 750.0
    power: str = "mi300x"


MI300X = GPUSpec("mi300x", peak_flops=1307e12, hbm_bw=5.3e12,
                 hbm_bytes=192e9, link_bw=64e9)
H100 = GPUSpec("h100", peak_flops=989e12, hbm_bw=3.35e12,
               hbm_bytes=80e9, link_bw=450e9,
               min_cap_w=300.0, max_cap_w=700.0, power="h100")
TPU_V5E = GPUSpec("tpu_v5e", peak_flops=197e12, hbm_bw=819e9,
                  hbm_bytes=16e9, link_bw=50e9, node_link_bw=25e9,
                  mfu_prefill=0.15, mbu_decode=0.48,
                  min_cap_w=110.0, max_cap_w=200.0, power="tpu_v5e")


@dataclasses.dataclass(frozen=True)
class CostModel:
    cfg: ModelConfig
    gpu: GPUSpec
    power: PowerModel
    dtype_bytes: int = 2

    def __post_init__(self):
        # Precompute the per-call invariants once. active_param_count() and
        # kv_bytes_per_token() walk the layer stack (O(n_layers)) — at one
        # call per simulated decode iteration they dominated the profile.
        c = self.cfg
        n_attn = sum(1 for k in c.layer_kinds() if k == "attn")
        kv_per_tok = 2 * n_attn * c.n_kv_heads * c.head_dim * self.dtype_bytes
        set_ = object.__setattr__        # frozen dataclass: explicit caches
        set_(self, "_kv_per_token", kv_per_tok)
        set_(self, "_active_params", c.active_param_count())
        set_(self, "_weight_bytes", self._active_params * self.dtype_bytes)
        # identical products to the inline expressions they replace, so the
        # cached path is bit-identical to recomputation
        set_(self, "_decode_bw", self.gpu.hbm_bw * self.gpu.mbu_decode)
        set_(self, "_prefill_flops_s",
             self.gpu.peak_flops * self.gpu.mfu_prefill)
        set_(self, "_memo_prefill", {})
        set_(self, "_memo_decode", {})
        set_(self, "_memo_rel", {})
        set_(self, "_memo_batch", {})

    # -- sizes ---------------------------------------------------------------
    def kv_bytes_per_token(self) -> float:
        return self._kv_per_token

    def weight_bytes(self) -> float:
        return self._weight_bytes

    def rel(self, role: str, cap_w: float) -> float:
        """Memoized power-curve multiplier (two ``math.exp`` per miss; caps
        take few distinct values so the hit rate is ~1)."""
        key = (role, cap_w)
        r = self._memo_rel.get(key)
        if r is None:
            r = self._memo_rel[key] = self.power.rel(role, cap_w)
        return r

    # -- phase times at a given power cap -------------------------------------
    def prefill_mfu(self) -> float:
        # Flat serving MFU, batch-size independent: the scheduler co-batches
        # small work (chunked prefill rides decode; small prompts batch
        # together) and long prompts' extra matmul efficiency is offset by
        # quadratic attention cost, which the 2*N*D flops term omits. This
        # constant is the Fig-5 calibration anchor (see EXPERIMENTS.md).
        return self.gpu.mfu_prefill

    def prefill_time(self, n_tokens: int, cap_w: float) -> float:
        """Process n_tokens of prompt (possibly batched across requests)."""
        key = (n_tokens, cap_w)
        t = self._memo_prefill.get(key)
        if t is None:
            if len(self._memo_prefill) > _MEMO_MAX:
                self._memo_prefill.clear()
            flops = 2.0 * self._active_params * n_tokens
            base = flops / self._prefill_flops_s
            t = self._memo_prefill[key] = (
                base / self.rel("prefill", cap_w)
                + self.gpu.overhead_prefill_s)
        return t

    def decode_step_time(self, batch: int, avg_ctx: int, cap_w: float) -> float:
        """One decode iteration for a continuous batch."""
        key = (batch, avg_ctx, cap_w)
        t = self._memo_decode.get(key)
        if t is None:
            if len(self._memo_decode) > _MEMO_MAX:
                self._memo_decode.clear()
            kv_traffic = self._kv_per_token * avg_ctx * batch
            base = (self._weight_bytes + kv_traffic) / self._decode_bw
            # small compute floor (projections for `batch` tokens)
            flops = 2.0 * self._active_params * max(batch, 1)
            base = max(base, flops / self._prefill_flops_s)
            t = self._memo_decode[key] = (
                base / self.rel("decode", cap_w)
                + self.gpu.overhead_decode_s)
        return t

    def kv_transfer_time(self, n_tokens: int) -> float:
        """Bulk KV-cache pull, prefill GPU -> decode GPU (counted in TPOT)."""
        return self._kv_per_token * n_tokens / self.gpu.link_bw

    def kv_migrate_time(self, ctx_tokens: int) -> float:
        """Cross-node migration of a live request: its whole KV cache
        (prompt + generated context) over the node interconnect. Orders of
        magnitude slower than the intra-node ring pull — the migration
        engine's drain→transfer→resume cost is dominated by this."""
        return self._kv_per_token * ctx_tokens / self.gpu.node_link_bw

    def max_decode_batch(self, avg_ctx: int) -> int:
        """KV capacity / scheduler bound for a decode GPU."""
        b = self._memo_batch.get(avg_ctx)
        if b is None:
            if len(self._memo_batch) > _MEMO_MAX:
                self._memo_batch.clear()
            free = 0.85 * self.gpu.hbm_bytes - self._weight_bytes
            cap = int(free / (self._kv_per_token * max(avg_ctx, 1)))
            b = self._memo_batch[avg_ctx] = \
                max(1, min(cap, self.gpu.max_active_decode))
        return b
