"""Analytical step-time model for disaggregated serving.

Gives prefill / decode / KV-transfer times for a (model, hardware, power)
triple. Prefill is compute-bound (scales with the power curve); decode is
HBM-bound (scales weakly, saturating by ~600 W) — the asymmetry RAPID
exploits. Constants for MI300X reproduce the paper's setting; TPU v5e
constants are provided for the target hardware.

Calibration sanity (Llama-3.1-8B, MI300X, 750 W): prefill 8k tokens
~ 2*8e9*8192 / (1307e12 * 0.5) = 0.20 s; decode step at batch 32 reads
16 GB weights + KV => ~4-6 ms/token. Both line up with the paper's SLO
regime (TTFT 1 s, TPOT 25-40 ms).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.core.power_model import PowerModel


@dataclasses.dataclass(frozen=True)
class GPUSpec:
    name: str
    peak_flops: float            # bf16, dense
    hbm_bw: float                # bytes/s
    hbm_bytes: float
    link_bw: float               # intra-node per-pair (XGMI / ICI / NVLink)
    # serving-efficiency calibration (vLLM-style single-GPU TP=1 serving,
    # includes scheduler/launch inefficiency; see EXPERIMENTS.md §Calibration)
    # prefill MFU saturates with batch tokens: mfu(n) = mfu_max*n/(n+n_half),
    # calibrated so mfu(4096) = 0.125 (matches the LongBench Fig-5 knees)
    mfu_max: float = 0.42
    mfu_n_half: float = 9667.0
    mfu_prefill: float = 0.125          # reference value at n = 4096
    mbu_decode: float = 0.34
    overhead_prefill_s: float = 0.03   # per prefill batch
    overhead_decode_s: float = 0.006   # per decode iteration
    max_active_decode: int = 64        # vLLM max_num_seqs-style cap
    # power envelope: cap range the vendor tool accepts, and the name of the
    # calibrated PowerCurve set (``core.power_model.get_power_model``) —
    # heterogeneous clusters resolve per-node curves from the node's spec
    min_cap_w: float = 400.0
    max_cap_w: float = 750.0
    power: str = "mi300x"


MI300X = GPUSpec("mi300x", peak_flops=1307e12, hbm_bw=5.3e12,
                 hbm_bytes=192e9, link_bw=64e9)
H100 = GPUSpec("h100", peak_flops=989e12, hbm_bw=3.35e12,
               hbm_bytes=80e9, link_bw=450e9,
               min_cap_w=300.0, max_cap_w=700.0, power="h100")
TPU_V5E = GPUSpec("tpu_v5e", peak_flops=197e12, hbm_bw=819e9,
                  hbm_bytes=16e9, link_bw=50e9, mfu_prefill=0.15,
                  mbu_decode=0.48,
                  min_cap_w=110.0, max_cap_w=200.0, power="tpu_v5e")


@dataclasses.dataclass(frozen=True)
class CostModel:
    cfg: ModelConfig
    gpu: GPUSpec
    power: PowerModel
    dtype_bytes: int = 2

    # -- sizes ---------------------------------------------------------------
    def kv_bytes_per_token(self) -> float:
        c = self.cfg
        n_attn = sum(1 for k in c.layer_kinds() if k == "attn")
        return 2 * n_attn * c.n_kv_heads * c.head_dim * self.dtype_bytes

    def weight_bytes(self) -> float:
        return self.cfg.active_param_count() * self.dtype_bytes

    # -- phase times at a given power cap -------------------------------------
    def prefill_mfu(self, n_tokens: int) -> float:
        # Flat serving MFU, batch-size independent: the scheduler co-batches
        # small work (chunked prefill rides decode; small prompts batch
        # together) and long prompts' extra matmul efficiency is offset by
        # quadratic attention cost, which the 2*N*D flops term omits. This
        # constant is the Fig-5 calibration anchor (see EXPERIMENTS.md).
        del n_tokens
        return self.gpu.mfu_prefill

    def prefill_time(self, n_tokens: int, cap_w: float) -> float:
        """Process n_tokens of prompt (possibly batched across requests)."""
        flops = 2.0 * self.cfg.active_param_count() * n_tokens
        base = flops / (self.gpu.peak_flops * self.prefill_mfu(n_tokens))
        return (base / self.power.rel("prefill", cap_w)
                + self.gpu.overhead_prefill_s)

    def decode_step_time(self, batch: int, avg_ctx: int, cap_w: float) -> float:
        """One decode iteration for a continuous batch."""
        weight_traffic = self.weight_bytes()
        kv_traffic = self.kv_bytes_per_token() * avg_ctx * batch
        base = (weight_traffic + kv_traffic) / (self.gpu.hbm_bw *
                                                self.gpu.mbu_decode)
        # small compute floor (projections for `batch` tokens)
        flops = 2.0 * self.cfg.active_param_count() * max(batch, 1)
        base = max(base, flops / (self.gpu.peak_flops * self.gpu.mfu_prefill))
        return (base / self.power.rel("decode", cap_w)
                + self.gpu.overhead_decode_s)

    def kv_transfer_time(self, n_tokens: int) -> float:
        """Bulk KV-cache pull, prefill GPU -> decode GPU (counted in TPOT)."""
        return self.kv_bytes_per_token() * n_tokens / self.gpu.link_bw

    def max_decode_batch(self, avg_ctx: int) -> int:
        """KV capacity / scheduler bound for a decode GPU."""
        free = 0.85 * self.gpu.hbm_bytes - self.weight_bytes()
        cap = int(free / (self.kv_bytes_per_token() * max(avg_ctx, 1)))
        return max(1, min(cap, self.gpu.max_active_decode))
