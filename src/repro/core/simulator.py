"""Discrete-event simulator of a power-capped disaggregated inference node.

Reproduces the paper's experimental setting on CPU: an 8-GPU MI300X node
(4800 W budget), vLLM-style central router + per-GPU workers, ring-buffer KV
handoff (32 slots, pull-based), continuous decode batching, chunked-prefill
coalesced baseline, and the RAPID controller (static / DynPower / DynGPU /
both). Step durations come from ``core.costmodel``; power from
``core.power_model``; the control algorithm is the *same code* that drives
the real-compute engine in ``serving/``.

Request lifecycle:
  arrival -> prefill queue -> prefill batch (token budget) -> ring slot ->
  KV transfer (counted against TPOT, paper Section 4) -> decode GPU
  (continuous batching) -> finish.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.controller import (ControllerConfig, Decision, NodeStress,
                                   Observation, RapidController, StaticPolicy,
                                   stress_from)
from repro.core.costmodel import MI300X, CostModel, GPUSpec
from repro.core.events import EventLoop
from repro.core.goodput import GoodputSummary, RequestRecord, summarize
from repro.core.power_manager import PowerManager
from repro.core.power_model import PowerModel, get_power_model

RING_SLOTS = 32
MAX_PREFILL_BATCH_TOKENS = 4096
MAX_PREFILL_BATCH_REQS = 8
PREFILL_CHUNK = 512               # coalesced chunked-prefill chunk size
CHUNK_PENALTY = 1.0               # chunked-prefill efficiency loss (Sarathi)
METRIC_WINDOW_S = 5.0


@dataclasses.dataclass
class SimRequest:
    rec: RequestRecord
    tokens_out: int = 0
    decode_gpu: Optional[int] = None
    preregistered: bool = False    # rec already counted in node records

    @property
    def rid(self):
        return self.rec.rid


@dataclasses.dataclass
class GPU:
    gid: int
    role: str                      # "prefill" | "decode" | "mixed"
    busy: bool = False
    draining: bool = False
    active: List[SimRequest] = dataclasses.field(default_factory=list)
    pending_join: List[SimRequest] = dataclasses.field(default_factory=list)
    iterating: bool = False
    # mixed-mode prefill progress: (req, tokens_done)
    mixed_prefill: deque = dataclasses.field(default_factory=deque)


class Workload:
    """List of requests with arrival times."""

    def __init__(self, entries, name=""):
        # entries: (arrival, in_tokens, out_tokens, ttft_slo, tpot_slo)
        self.entries = sorted(entries, key=lambda e: e[0])
        self.name = name

    @staticmethod
    def poisson_arrivals(n: int, qps: float, rng) -> np.ndarray:
        gaps = rng.exponential(1.0 / qps, n)
        return np.cumsum(gaps)

    @classmethod
    def longbench_like(cls, n: int, qps: float, seed=0, max_input=8192,
                       ttft_slo=1.0, tpot_slo=0.040):
        """Long-tailed input lengths up to 8k (paper Section 4)."""
        rng = np.random.default_rng(seed)
        t = cls.poisson_arrivals(n, qps, rng)
        lens = np.minimum((rng.lognormal(7.6, 0.9, n)).astype(int) + 64,
                          max_input)
        outs = rng.integers(384, 896, n)
        return cls([(float(t[i]), int(lens[i]), int(outs[i]), ttft_slo,
                     tpot_slo) for i in range(n)], name="longbench")

    @classmethod
    def sonnet_phases(cls, qps: float, seed=0, n1=1000, n2=1000,
                      ttft_slo=1.0, tpot1=0.040, tpot2=0.020):
        """Paper Section 5.2: prefill-heavy phase (8k in / 128 out, 40 ms)
        then decode-heavy phase (500 in / 500 out, 20 ms)."""
        rng = np.random.default_rng(seed)
        t1 = cls.poisson_arrivals(n1, qps, rng)
        t2 = cls.poisson_arrivals(n2, qps, rng) + t1[-1]
        e = [(float(t), 8192, 128, ttft_slo, tpot1) for t in t1]
        e += [(float(t), 500, 500, ttft_slo, tpot2) for t in t2]
        return cls(e, name="sonnet")

    @classmethod
    def uniform(cls, n: int, qps: float, in_tokens: int, out_tokens: int,
                seed=0, ttft_slo=1.0, tpot_slo=0.040):
        rng = np.random.default_rng(seed)
        t = cls.poisson_arrivals(n, qps, rng)
        return cls([(float(tt), in_tokens, out_tokens, ttft_slo, tpot_slo)
                    for tt in t], name="uniform")


class NodeSimulator:
    """One power-capped 8-GPU node. Owns its queues/roles/power manager;
    the *clock* is an ``EventLoop`` that may be private (single-node ``run``)
    or shared with sibling nodes by a cluster simulator (``core.cluster``)."""

    def __init__(self, cfg: ModelConfig, policy: StaticPolicy,
                 node_budget_w: float = 4800.0,
                 gpu: GPUSpec = MI300X, power: Optional[PowerModel] = None,
                 ctrl_cfg: Optional[ControllerConfig] = None,
                 coalesced: bool = False, seed: int = 0,
                 min_cap_w: Optional[float] = None,
                 max_cap_w: Optional[float] = None,
                 loop: Optional[EventLoop] = None, node_id: int = 0):
        self.node_id = node_id
        # power curves and the cap range both default from the GPU spec, so a
        # heterogeneous cluster gets per-node envelopes without extra plumbing
        self.cost = CostModel(cfg, gpu, power or get_power_model(gpu.power))
        self.n_gpus = policy.n_prefill + policy.n_decode
        caps = policy.caps()
        assert sum(caps) <= node_budget_w + 1e-6, (caps, node_budget_w)
        self.pm = PowerManager(self.n_gpus, node_budget_w, initial_caps=caps,
                               min_cap=min_cap_w if min_cap_w is not None
                               else gpu.min_cap_w,
                               max_cap=max_cap_w if max_cap_w is not None
                               else gpu.max_cap_w)
        self.coalesced = coalesced
        if coalesced:
            self.gpus = [GPU(i, "mixed") for i in range(self.n_gpus)]
        else:
            self.gpus = ([GPU(i, "prefill") for i in range(policy.n_prefill)] +
                         [GPU(policy.n_prefill + i, "decode")
                          for i in range(policy.n_decode)])
        self.ctrl = (RapidController(ctrl_cfg, self.pm) if ctrl_cfg else None)
        self.ctrl_cfg = ctrl_cfg
        self.rng = np.random.default_rng(seed)

        self.loop = loop or EventLoop()
        self.q_prefill: deque = deque()
        self.ring_free = RING_SLOTS
        self.ring_wait: deque = deque()
        self.records: List[RequestRecord] = []
        self.recent_ttft: deque = deque()       # (t, value)
        self.recent_tpot: deque = deque()       # decode iteration times
        self.recent_req_tpot: deque = deque()   # completed-request TPOT
        self.power_samples: List[tuple] = []    # (t, provisioned, roles)
        self.trace_caps: List[tuple] = []       # (t, caps per gpu, roles)
        self.mixed_rr = 0
        self.finished_count = 0    # O(1) termination checks for the loop
        self._ext_flip_gids: set = set()   # coordinator-requested drains

    # ---------------- event plumbing ----------------
    @property
    def now(self) -> float:
        return self.loop.now

    def _push(self, t: float, kind: str, payload=None):
        self.loop.push(t, self.handle, kind, payload)

    # ---------------- role lists ----------------
    def prefill_gpus(self) -> List[int]:
        return [g.gid for g in self.gpus if g.role == "prefill"
                and not g.draining]

    def decode_gpus(self) -> List[int]:
        return [g.gid for g in self.gpus if g.role == "decode"
                and not g.draining]

    # ---------------- prefill ----------------
    def _kick_prefill(self, gpu: GPU):
        if gpu.busy or gpu.draining or not self.q_prefill:
            return
        batch, tokens = [], 0
        while (self.q_prefill and len(batch) < MAX_PREFILL_BATCH_REQS and
               tokens < MAX_PREFILL_BATCH_TOKENS):
            nxt = self.q_prefill[0]
            if batch and tokens + nxt.rec.input_tokens > MAX_PREFILL_BATCH_TOKENS:
                break
            self.q_prefill.popleft()
            batch.append(nxt)
            tokens += nxt.rec.input_tokens
        if not batch:
            return
        gpu.busy = True
        cap = self.pm.effective[gpu.gid]
        dt = self.cost.prefill_time(tokens, cap)
        self._push(self.now + dt, "prefill_done", (gpu.gid, batch))

    def _on_prefill_done(self, gid: int, batch: List[SimRequest]):
        gpu = self.gpus[gid]
        gpu.busy = False
        for req in batch:
            req.rec.prefill_done = self.now
            self.recent_ttft.append((self.now, req.rec.ttft))
            self._ring_enqueue(req)
        if gpu.draining:
            self._push(self.now + self._drain_s(), "drain_done", gid)
        else:
            self._kick_prefill(gpu)

    # ---------------- KV ring buffer ----------------
    def _ring_enqueue(self, req: SimRequest):
        self.ring_wait.append(req)
        self._ring_pump()

    def _ring_pump(self):
        while self.ring_free > 0 and self.ring_wait:
            req = self.ring_wait.popleft()
            self.ring_free -= 1
            dt = self.cost.kv_transfer_time(req.rec.input_tokens)
            self._push(self.now + dt, "transfer_done", req)

    def _on_transfer_done(self, req: SimRequest):
        dgpus = self.decode_gpus() or [g.gid for g in self.gpus
                                       if g.role == "decode"]
        load = lambda i: len(self.gpus[i].active) + len(self.gpus[i].pending_join)
        cap = self.cost.max_decode_batch(int(self._global_avg_ctx()))
        if not dgpus or min((load(i) for i in dgpus), default=cap) >= cap:
            # decode pool saturated: request stays in its ring slot
            # (backpressure on prefill, paper Section 3.3)
            self._push(self.now + 0.02, "transfer_done", req)
            return
        self.ring_free += 1
        self._ring_pump()
        gid = min(dgpus, key=load)
        req.decode_gpu = gid
        gpu = self.gpus[gid]
        gpu.pending_join.append(req)
        self._kick_decode(gpu)

    def _global_avg_ctx(self) -> float:
        ctxs = [r.rec.input_tokens + r.tokens_out
                for g in self.gpus for r in g.active]
        return float(np.mean(ctxs)) if ctxs else 1000.0

    # ---------------- decode ----------------
    def _avg_ctx(self, gpu: GPU) -> float:
        if not gpu.active:
            return 1.0
        return float(np.mean([r.rec.input_tokens + r.tokens_out
                              for r in gpu.active]))

    def _kick_decode(self, gpu: GPU):
        if gpu.iterating:
            return
        gpu.active.extend(gpu.pending_join)
        gpu.pending_join.clear()
        if not gpu.active:
            return
        gpu.iterating = True
        cap = self.pm.effective[gpu.gid]
        dt = self.cost.decode_step_time(len(gpu.active), self._avg_ctx(gpu), cap)
        self._push(self.now + dt, "decode_iter", (gpu.gid, dt))

    def _on_decode_iter(self, gid: int, dt: float):
        gpu = self.gpus[gid]
        gpu.iterating = False
        self.recent_tpot.append((self.now, dt))
        done = []
        for r in gpu.active:
            r.tokens_out += 1
            if r.tokens_out >= r.rec.output_tokens:
                r.rec.finish = self.now
                self.finished_count += 1
                self.recent_req_tpot.append((self.now, r.rec.tpot))
                done.append(r)
        gpu.active = [r for r in gpu.active if r.rec.finish is None]
        if gpu.draining and not gpu.active:
            self._push(self.now + self._drain_s(), "drain_done", gid)
            return
        self._kick_decode(gpu)

    # ---------------- coalesced (chunked prefill, Sarathi-style) ----------
    def _kick_mixed(self, gpu: GPU):
        if gpu.iterating:
            return
        gpu.active.extend(gpu.pending_join)
        gpu.pending_join.clear()
        if not gpu.mixed_prefill and not gpu.active:
            return
        gpu.iterating = True
        cap = self.pm.effective[gpu.gid]
        if gpu.mixed_prefill:
            req, done_toks = gpu.mixed_prefill[0]
            chunk = min(PREFILL_CHUNK, req.rec.input_tokens - done_toks)
            dt = self.cost.prefill_time(chunk, cap) * CHUNK_PENALTY
            if gpu.active:   # decode KV traffic rides the fused iteration
                dt += (self.cost.kv_bytes_per_token() * self._avg_ctx(gpu) *
                       len(gpu.active)) / (self.cost.gpu.hbm_bw *
                                           self.cost.gpu.mbu_decode)

            self._push(self.now + dt, "mixed_iter", (gpu.gid, dt, chunk))
        else:
            dt = self.cost.decode_step_time(len(gpu.active),
                                            self._avg_ctx(gpu), cap)
            self._push(self.now + dt, "mixed_iter", (gpu.gid, dt, 0))

    def _on_mixed_iter(self, gid: int, dt: float, chunk: int):
        gpu = self.gpus[gid]
        gpu.iterating = False
        if chunk and gpu.mixed_prefill:
            req, done_toks = gpu.mixed_prefill.popleft()
            done_toks += chunk
            if done_toks >= req.rec.input_tokens:
                req.rec.prefill_done = self.now
                self.recent_ttft.append((self.now, req.rec.ttft))
                gpu.pending_join.append(req)   # same GPU continues decoding
            else:
                gpu.mixed_prefill.appendleft((req, done_toks))
        if gpu.active:
            self.recent_tpot.append((self.now, dt))
            done = []
            for r in gpu.active:
                r.tokens_out += 1
                if r.tokens_out >= r.rec.output_tokens:
                    r.rec.finish = self.now
                    self.finished_count += 1
            gpu.active = [r for r in gpu.active if r.rec.finish is None]
        self._kick_mixed(gpu)

    # ---------------- controller ----------------
    def _window_p90(self, dq: deque) -> float:
        while dq and dq[0][0] < self.now - METRIC_WINDOW_S:
            dq.popleft()
        if not dq:
            return 0.0
        return float(np.percentile([v for _, v in dq], 90))

    def _queue_ttft_estimate(self) -> float:
        """Pessimistic TTFT signal from queue head age (early warning)."""
        if not self.q_prefill:
            return 0.0
        head = self.q_prefill[0]
        return self.now - head.rec.arrival

    def _drain_s(self) -> float:
        return (self.ctrl_cfg.gpu_move_drain_s if self.ctrl_cfg else 3.0)

    def _on_ctrl(self):
        self.pm.tick(self.now)
        self.trace_caps.append((self.now, list(self.pm.effective),
                                [g.role for g in self.gpus]))
        self.power_samples.append((self.now, sum(self.pm.effective)))
        if self.ctrl is not None and not self.coalesced:
            obs = self.observe()
            pre, dec = self.prefill_gpus(), self.decode_gpus()
            d = self.ctrl.tick(obs, pre, dec)
            if d.kind == "power":
                src, dst = (dec, pre) if d.direction == "d2p" else (pre, dec)
                dst_max = (self.ctrl_cfg.decode_cap_max_w
                           if d.direction == "p2d" else self.pm.max_cap)
                # lower each source by one step; never below min
                t_ready, freed = self.pm.shift(self.now, src, dst,
                                               self.ctrl_cfg.power_step_w)
                # sink raise after sources enforced; payload rides the event
                self._push(t_ready, "power_ready", (list(dst), freed, dst_max))
            elif d.kind == "gpu":
                self._start_role_switch(d.direction)
        if self.loop.heap:
            self._push(self.now + (self.ctrl_cfg.min_time_s
                                   if self.ctrl_cfg else 0.25), "ctrl")

    def can_flip(self, direction: str) -> bool:
        """Whether a role flip in ``direction`` would leave the node with at
        least the configured minimum of source-role GPUs."""
        if self.coalesced:
            return False
        if direction == "d2p":
            return len(self.decode_gpus()) > (self.ctrl_cfg.min_decode_gpus
                                              if self.ctrl_cfg else 1)
        return len(self.prefill_gpus()) > (self.ctrl_cfg.min_prefill_gpus
                                           if self.ctrl_cfg else 1)

    def request_role_flip(self, direction: str) -> bool:
        """Externally-requested MoveGPU (cluster coordinator): start draining
        one GPU toward the opposite role. Same drain discipline as the node
        controller's own GPU moves; completion is announced on the shared
        loop as a ``role_flip`` event with ``external=True`` so the
        coordinator can tell its own flips from the node controller's.
        Returns False if refused (coalesced node or at the role minimum)."""
        if not self.can_flip(direction):
            return False
        gid = self._start_role_switch(direction)
        if gid is None:
            return False
        self._ext_flip_gids.add(gid)
        return True

    def _start_role_switch(self, direction: str) -> Optional[int]:
        """Pick and drain one GPU toward the opposite role; returns its gid
        (or None if refused at the role minimum)."""
        if direction == "d2p":
            cands = self.decode_gpus()
            if len(cands) <= (self.ctrl_cfg.min_decode_gpus
                              if self.ctrl_cfg else 1):
                return None
            gid = min(cands, key=lambda i: len(self.gpus[i].active))
            gpu = self.gpus[gid]
            gpu.draining = True
            # migrate its active requests to remaining decode GPUs
            others = [i for i in self.decode_gpus() if i != gid]
            if others and gpu.active:
                for r in gpu.active:
                    tgt = min(others, key=lambda i: len(self.gpus[i].active))
                    r.decode_gpu = tgt
                    self.gpus[tgt].pending_join.append(r)
                gpu.active = []
                for i in others:
                    self._kick_decode(self.gpus[i])
            self._push(self.now + self._drain_s(), "drain_done", gid)
        else:
            cands = self.prefill_gpus()
            if len(cands) <= (self.ctrl_cfg.min_prefill_gpus
                              if self.ctrl_cfg else 1):
                return None
            gid = min(cands, key=lambda i: self.gpus[i].busy)
            gpu = self.gpus[gid]
            gpu.draining = True
            if not gpu.busy:
                self._push(self.now + self._drain_s(), "drain_done", gid)
            # else drain scheduled on prefill completion
        return gid

    def _on_drain_done(self, gid: int):
        gpu = self.gpus[gid]
        if not gpu.draining:      # duplicate drain event (already flipped)
            return
        gpu.draining = False
        gpu.role = "prefill" if gpu.role == "decode" else "decode"
        # Algorithm 1 line 14: uniform power after a GPU move
        t_ready, gpus, per = self.pm.distribute_uniform(self.now)
        self._push(t_ready, "uniform_ready", (gpus, per))
        # announce the completed flip (cluster coordinator, if any, clears
        # its in-flight tracking and re-asserts the facility invariant);
        # external=True iff this drain was coordinator-requested, so its
        # completion is never confused with a node-controller flip
        external = gid in self._ext_flip_gids
        self._ext_flip_gids.discard(gid)
        self.loop.publish("role_flip", (self.node_id, gid, gpu.role,
                                        external))
        if gpu.role == "prefill":
            self._kick_prefill(gpu)
        else:
            self._kick_decode(gpu)

    # ---------------- cluster-facing signals ----------------
    def queued_prefill_tokens(self) -> int:
        toks = sum(r.rec.input_tokens for r in self.q_prefill)
        toks += sum(max(req.rec.input_tokens - done, 0)
                    for g in self.gpus for req, done in g.mixed_prefill)
        return toks

    def prefill_capacity_tps(self) -> float:
        """Effective prefill-role capacity: aggregate token rate of the
        non-draining prefill GPUs at their *current* caps, through this
        node's own cost model — so a 4-GPU H100 pool and a 4-GPU MI300X pool
        report their real (different) rates, and a mid-drain role flip is
        reflected the moment the GPU leaves the role list. The rate is
        amortized over a full prefill batch so per-batch overhead is
        counted once, like the scheduler pays it."""
        pre = self.prefill_gpus() or [g.gid for g in self.gpus
                                      if not g.draining]
        return sum(
            MAX_PREFILL_BATCH_TOKENS /
            self.cost.prefill_time(MAX_PREFILL_BATCH_TOKENS,
                                   self.pm.effective[g])
            for g in pre)

    def router_load(self, extra_tokens: int = 0) -> float:
        """Power-adjusted load signal for the cluster router: estimated time
        to drain the queued prefill work (plus ``extra_tokens`` of the
        arriving request, making the signal a *marginal* cost) through this
        node's effective role capacity, plus the queue-head-age early
        warning (same signal the controller uses via
        ``_queue_ttft_estimate``)."""
        rate = self.prefill_capacity_tps()
        if rate <= 0.0:
            return float("inf")
        toks = self.queued_prefill_tokens() + extra_tokens
        return toks / rate + self._queue_ttft_estimate()

    def observe(self) -> Observation:
        """Current controller observation (also the coordinator's view —
        both MUST see the same metric definition)."""
        return Observation(
            now=self.now,
            ttft_p90=max(self._window_p90(self.recent_ttft),
                         self._queue_ttft_estimate()),
            tpot_p90=max(self._window_p90(self.recent_tpot),
                         self._window_p90(self.recent_req_tpot)),
            q_prefill=len(self.q_prefill),
            q_decode=(sum(len(g.pending_join) for g in self.gpus)
                      + len(self.ring_wait)),
        )

    def stress_summary(self) -> NodeStress:
        """SLO-relative stress for the cluster coordinator (works with or
        without a per-node controller)."""
        ttft_slo = self.ctrl_cfg.ttft_slo if self.ctrl_cfg else 1.0
        tpot_slo = self.ctrl_cfg.tpot_slo if self.ctrl_cfg else 0.040
        return stress_from(self.observe(), ttft_slo, tpot_slo,
                           node_id=self.node_id)

    # ---------------- main loop ----------------
    def submit(self, req: SimRequest):
        """Accept a request at the current time (called from the arrival
        event in single-node mode, or by the cluster router)."""
        if not req.preregistered:
            self.records.append(req.rec)
            req.preregistered = True
        if self.coalesced:
            gpu = self.gpus[self.mixed_rr % self.n_gpus]
            self.mixed_rr += 1
            gpu.mixed_prefill.append((req, 0))
            self._kick_mixed(gpu)
        else:
            self.q_prefill.append(req)
            for gid in self.prefill_gpus():
                self._kick_prefill(self.gpus[gid])

    def start(self):
        """Schedule the periodic control/sampling tick."""
        self._push(self.loop.now, "ctrl")

    def n_unfinished(self) -> int:
        return len(self.records) - self.finished_count

    def handle(self, kind: str, payload=None):
        """Event sink: all node events dispatch through here."""
        self.pm.tick(self.now)
        if kind == "arrival":
            self.submit(payload)
        elif kind == "prefill_done":
            self._on_prefill_done(*payload)
        elif kind == "transfer_done":
            self._on_transfer_done(payload)
        elif kind == "decode_iter":
            self._on_decode_iter(*payload)
        elif kind == "mixed_iter":
            self._on_mixed_iter(*payload)
        elif kind == "ctrl":
            self._on_ctrl()
        elif kind == "power_ready":
            dst, freed, dst_max = payload
            self.pm.apply_raise(self.now, dst, freed, dst_max)
        elif kind == "uniform_ready":
            gpus, per = payload
            self.pm.apply_uniform(self.now, gpus, per)
        elif kind == "drain_done":
            self._on_drain_done(payload)
        else:
            raise ValueError(f"unknown event kind {kind!r}")

    def summary(self) -> GoodputSummary:
        duration = max((r.finish or self.now) for r in self.records) if \
            self.records else self.now
        if self.power_samples:
            avg_w = float(np.mean([w for _, w in self.power_samples]))
        else:
            avg_w = sum(self.pm.effective)
        return summarize(self.records, duration, avg_w)

    def run(self, workload: Workload, horizon_s: float = 1e5) -> GoodputSummary:
        """Single-node entry point: drives a private event loop to completion
        (cluster runs are driven by ``core.cluster.ClusterSimulator``).
        All records are registered upfront so a horizon-truncated run still
        counts never-arrived requests against SLO attainment."""
        for i, (t, it, ot, ts, ps) in enumerate(workload.entries):
            rec = RequestRecord(i, t, it, ot, ttft_slo=ts, tpot_slo=ps)
            self.records.append(rec)
            self._push(t, "arrival", SimRequest(rec, preregistered=True))
        self.start()
        self.loop.run(lambda: self.n_unfinished() == 0, horizon_s)
        return self.summary()
