"""Discrete-event simulator of a power-capped disaggregated inference node.

Reproduces the paper's experimental setting on CPU: an 8-GPU MI300X node
(4800 W budget), vLLM-style central router + per-GPU workers, ring-buffer KV
handoff (32 slots, pull-based), continuous decode batching, chunked-prefill
coalesced baseline, and the RAPID controller (static / DynPower / DynGPU /
both). Step durations come from ``core.costmodel``; power from
``core.power_model``; the control algorithm is the *same code* that drives
the real-compute engine in ``serving/``.

Request lifecycle:
  arrival -> prefill queue -> prefill batch (token budget) -> ring slot ->
  KV transfer (counted against TPOT, paper Section 4) -> decode GPU
  (continuous batching) -> finish.

Macro-stepping (``fidelity="macro"``, the default): a decode GPU's batch
composition can only change at *event boundaries* — a request finishing, a
join merging, a drain migrating the batch away, or a power-cap change — so
between boundaries the per-iteration times are fully determined. Instead of
one heap event per decode iteration, the simulator plans the whole run of
iterations up to the next boundary (first finish / pending cap change /
chunk limit) and schedules ONE ``macro_done`` event at its end. Three rules
keep it bit-identical to the per-iteration path (``fidelity="iter"``, kept
for the golden-equivalence test):

  * every event dispatch first *syncs*: iterations whose end time has
    passed are materialized (token counts, TPOT window entries, power-
    manager tick) before any handler reads state;
  * a mid-plan state change that *would* have altered a future iteration
    (a join arriving, a cap commanded or taking effect, a drain migration)
    truncates the plan at the in-flight iteration's end — exactly where the
    per-iteration path would have re-read the world;
  * per-iteration times inside a plan are computed with the identical
    float operations the per-iteration path uses (the running context mean
    is exact integer arithmetic), and end times accumulate sequentially,
    so every timestamp matches to the last bit.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Any, Dict, List, Optional

import numpy as np

from repro.analysis.check.sanitize import InvariantSanitizer, sanitize_enabled
from repro.configs.base import ModelConfig
from repro.core.controller import (ControllerConfig, NodeStress, Observation,
                                   RapidController, StaticPolicy, stress_from)
from repro.core.costmodel import MI300X, CostModel, GPUSpec
from repro.core.events import EventLoop
from repro.core.goodput import GoodputSummary, RequestRecord, summarize
from repro.core.power_manager import PowerManager
from repro.core.power_model import PowerModel, get_power_model
from repro.core.prefixcache import PrefixBlock, PrefixCache, PrefixCacheConfig
from repro.core.tenancy import TenantRegistry

RING_SLOTS = 32
MAX_PREFILL_BATCH_TOKENS = 4096
MAX_PREFILL_BATCH_REQS = 8
PREFILL_CHUNK = 512               # coalesced chunked-prefill chunk size
CHUNK_PENALTY = 1.0               # chunked-prefill efficiency loss (Sarathi)
METRIC_WINDOW_S = 5.0
MACRO_CHUNK = 1024                # max decode iterations planned per event


class MetricWindow:
    """Sliding-window metric samples on preallocated numpy buffers: O(1)
    appends, block extends (macro materialization lands whole iteration
    runs in one slice assignment), and exact vectorized p90 reads.

    Eviction is order-insensitive (a ``t >= cutoff`` mask), so macro
    materialization may append per-GPU blocks with interleaved timestamps
    without any sorting — the surviving multiset, and hence the percentile,
    is exactly what a time-sorted pop-left eviction would produce. Dead
    prefixes advance ``head``; storage compacts when the dead span wins.

    ``p90`` mirrors ``np.percentile(..., 90)`` arithmetic exactly (same
    virtual index, same two-sided lerp) via ``np.partition`` — verified
    bit-identical — at a fraction of the overhead."""

    __slots__ = ("ts", "vs", "n", "head", "_memo")

    def __init__(self):
        self.ts = np.empty(256)
        self.vs = np.empty(256)
        self.n = 0
        self.head = 0
        self._memo = (math.nan, -1, 0.0)    # (cutoff, n, result)

    def _grow(self, need: int) -> None:
        cap = len(self.ts)
        while cap < need:
            cap *= 2
        ts, vs = np.empty(cap), np.empty(cap)
        ts[:self.n] = self.ts[:self.n]
        vs[:self.n] = self.vs[:self.n]
        self.ts, self.vs = ts, vs

    def append(self, t: float, v: float) -> None:
        n = self.n
        if n == len(self.ts):
            self._grow(n + 1)
        self.ts[n] = t
        self.vs[n] = v
        self.n = n + 1

    def extend(self, ts: np.ndarray, vs: np.ndarray) -> None:
        n, k = self.n, len(ts)
        if n + k > len(self.ts):
            self._grow(n + k)
        self.ts[n:n + k] = ts
        self.vs[n:n + k] = vs
        self.n = n + k

    def __len__(self) -> int:
        return self.n - self.head

    def __iter__(self):
        """(t, v) pairs currently stored (analysis/debug use)."""
        return zip(self.ts[self.head:self.n].tolist(),
                   self.vs[self.head:self.n].tolist())

    def p90(self, cutoff: float) -> float:
        h, n = self.head, self.n
        if h >= n:
            return 0.0
        # co-timed readers (node controller + cluster coordinator at the
        # same instant) recompute nothing: the alive set is a pure function
        # of (cutoff, n) — head advances never change it
        memo = self._memo
        if cutoff == memo[0] and n == memo[1]:
            return memo[2]
        if n - h <= 48:
            # scalar path: small windows (per-request TTFT/TPOT samples)
            # are numpy-overhead-bound; identical IEEE arithmetic
            pairs = [(t, v) for t, v in zip(self.ts[h:n].tolist(),
                                            self.vs[h:n].tolist())
                     if t >= cutoff]
            if not pairs:
                self.head = n
                return 0.0
            vs = sorted(v for _, v in pairs)
            r = self._lerp90(vs, len(vs))
        else:
            alive = self.ts[h:n] >= cutoff
            k = int(alive.sum())
            if k == 0:
                self.head = n
                return 0.0
            if k == n - h:
                vals = self.vs[h:n]
            else:
                if not alive[0]:            # advance past the dead prefix
                    first = int(alive.argmax())
                    h = self.head = h + first
                    alive = alive[first:]
                    if h > 8192 and h * 2 > n:    # compact the dead span
                        self.ts[:n - h] = self.ts[h:n].copy()
                        self.vs[:n - h] = self.vs[h:n].copy()
                        self.n, self.head, h = n - h, 0, 0
                        n = self.n
                vals = self.vs[h:n]
                if k != n - h:
                    vals = vals[alive]
            # exact np.percentile(vals, 90), method="linear"
            if k > 128:
                virt = 0.9 * (k - 1)
                j = int(virt)
                if j + 1 < k:
                    part = np.partition(vals, (j, j + 1))
                    a, b = float(part[j]), float(part[j + 1])
                else:
                    a = b = float(np.partition(vals, j)[j])
                g = virt - j
                d = b - a
                r = (b - d * (1 - g)) if g >= 0.5 else (a + d * g)
            else:
                r = self._lerp90(sorted(vals.tolist()), k)
        self._memo = (cutoff, n, r)
        return r

    @staticmethod
    def _lerp90(vs_sorted, m: int) -> float:
        """np.percentile(…, 90, method="linear") on a sorted value list —
        same virtual index and two-sided lerp, bit-identical."""
        virt = 0.9 * (m - 1)
        j = int(virt)
        g = virt - j
        a = vs_sorted[j]
        b = vs_sorted[j + 1] if j + 1 < m else a
        d = b - a
        if g >= 0.5:
            return b - d * (1 - g)
        return a + d * g


@dataclasses.dataclass(eq=False)     # identity semantics: hashable, tracked
class SimRequest:                    # by object in the in-flight tables
    rec: RequestRecord
    tokens_out: int = 0
    decode_gpu: Optional[int] = None
    preregistered: bool = False    # rec already counted in node records
    # Macro-stepping: ``tokens_out`` is exact only relative to the owning
    # GPU's ``tok_epoch`` — true count = tokens_out + (gpu.tok_epoch -
    # tok_mark). Folded back into ``tokens_out`` at every plan boundary
    # (join/finish/migration), so outside a running plan it is exact.
    tok_mark: int = 0
    # energy accounting: ``rec.energy_j`` is exact up to the GPU's
    # ``energy_epoch`` at ``e_mark``; the outstanding segment
    # (energy_epoch - e_mark) folds in ONLY when the request finishes or
    # leaves the GPU — the same instants under both fidelities, so the
    # accumulated float sums match to the last bit.
    e_mark: float = 0.0
    # prefix locality (core.prefixcache): the request's session path and
    # per-level segment token counts; ``cached_tokens`` is set at prefill
    # launch to the tokens actually served from the node's cache;
    # ``carried_block`` is a detached cache leaf riding a KV migration
    prefix_key: tuple = ()
    prefix_tokens: tuple = ()
    cached_tokens: int = 0
    carried_block: Optional[PrefixBlock] = None

    @property
    def rid(self) -> int:
        return self.rec.rid

    def reset_for_requeue(self) -> None:
        """KV and generated tokens are gone (node failure, or a migration
        written off past its deadline); the request re-enters through the
        router from scratch. The spent joules are NOT reset — wasted work
        stays on the bill."""
        self.tokens_out = 0
        self.tok_mark = 0
        self.e_mark = 0.0
        self.decode_gpu = None
        self.rec.prefill_done = None
        self.cached_tokens = 0
        self.carried_block = None    # detached prefix KV dies with the rest


class MacroPlan:
    """A planned run of decode iterations at fixed batch composition/cap.

    ``end_times[i]`` is the absolute completion time of planned iteration i
    (sequentially accumulated floats — identical to per-event scheduling);
    ``m`` counts iterations already materialized into simulator state.
    Both arrays are float64 numpy arrays, so materialization lands whole
    runs into the TPOT window as slice copies and truncation is a view.
    Plain __slots__ class: one is built per planned run, on the hot path."""

    __slots__ = ("gen", "end_times", "dts", "e_ends", "capv", "m")

    def __init__(self, gen: int, end_times: np.ndarray, dts: np.ndarray,
                 e_ends: np.ndarray, capv: int) -> None:
        self.gen = gen             # matches GPU.gen; stale events ignored
        self.end_times = end_times
        self.dts = dts
        self.e_ends = e_ends       # cumulative per-request joules epochs
        self.capv = capv           # PowerManager.cap_version[gid] snapshot
        self.m = 0


@dataclasses.dataclass
class GPU:
    gid: int
    role: str                      # "prefill" | "decode" | "mixed"
    busy: bool = False
    draining: bool = False
    active: List[SimRequest] = dataclasses.field(default_factory=list)
    pending_join: List[SimRequest] = dataclasses.field(default_factory=list)
    iterating: bool = False
    # mixed-mode prefill progress: (req, tokens_done)
    mixed_prefill: deque = dataclasses.field(default_factory=deque)
    # incremental sum of (input_tokens + tokens_out) over ``active`` — keeps
    # the per-iteration context mean O(1) instead of rescanning the batch
    ctx_sum: int = 0
    # macro-stepping state (fidelity="macro"): ``tok_epoch`` counts decode
    # iterations this GPU has materialized — advancing it IS the whole
    # batch's token update (requests fold the delta in at plan boundaries)
    plan: Optional[MacroPlan] = None
    gen: int = 0
    tok_epoch: int = 0
    # cumulative joules a request sitting in this GPU's batch has been
    # charged since GPU creation (each decode iteration adds draw*dt/batch);
    # requests carry an ``e_mark`` into it. Advanced sequentially under
    # ``fidelity="iter"`` and via the cumsum-as-left-fold under ``"macro"``,
    # so the epoch values agree bit-for-bit at every fold instant.
    energy_epoch: float = 0.0
    # in-flight prefill batch (fleet failure eviction needs to recover
    # requests whose only reference otherwise lives in an event payload)
    inflight_prefill: Optional[List[SimRequest]] = None
    # adaptive plan-length hint: ~4x the last realized run length (floor 64,
    # where the vectorized path takes over), so plan computation is not
    # wasted when joins keep cutting plans short, but grows geometrically
    # toward MACRO_CHUNK during long undisturbed decode
    k_hint: int = 64


class Workload:
    """List of requests with arrival times."""

    def __init__(self, entries: List[tuple], name: str = "") -> None:
        # entries: (arrival, in_tokens, out_tokens, ttft_slo, tpot_slo)
        # with two optional trailing fields for multi-tenant workloads:
        # [5] tenant name, [6] (prefix_path, prefix_seg_tokens) — see
        # ``build_request`` for the single decoding point
        self.entries = sorted(entries, key=lambda e: e[0])
        self.name = name

    @staticmethod
    def poisson_arrivals(n: int, qps: float,
                         rng: np.random.Generator) -> np.ndarray:
        gaps = rng.exponential(1.0 / qps, n)
        return np.cumsum(gaps)

    @classmethod
    def longbench_like(cls, n: int, qps: float, seed: int = 0,
                       max_input: int = 8192, ttft_slo: float = 1.0,
                       tpot_slo: float = 0.040) -> "Workload":
        """Long-tailed input lengths up to 8k (paper Section 4)."""
        rng = np.random.default_rng(seed)
        t = cls.poisson_arrivals(n, qps, rng)
        lens = np.minimum((rng.lognormal(7.6, 0.9, n)).astype(int) + 64,
                          max_input)
        outs = rng.integers(384, 896, n)
        return cls([(float(t[i]), int(lens[i]), int(outs[i]), ttft_slo,
                     tpot_slo) for i in range(n)], name="longbench")

    @classmethod
    def sonnet_phases(cls, qps: float, seed: int = 0, n1: int = 1000,
                      n2: int = 1000, ttft_slo: float = 1.0,
                      tpot1: float = 0.040,
                      tpot2: float = 0.020) -> "Workload":
        """Paper Section 5.2: prefill-heavy phase (8k in / 128 out, 40 ms)
        then decode-heavy phase (500 in / 500 out, 20 ms)."""
        rng = np.random.default_rng(seed)
        t1 = cls.poisson_arrivals(n1, qps, rng)
        t2 = cls.poisson_arrivals(n2, qps, rng) + t1[-1]
        e = [(float(t), 8192, 128, ttft_slo, tpot1) for t in t1]
        e += [(float(t), 500, 500, ttft_slo, tpot2) for t in t2]
        return cls(e, name="sonnet")

    @classmethod
    def uniform(cls, n: int, qps: float, in_tokens: int, out_tokens: int,
                seed: int = 0, ttft_slo: float = 1.0,
                tpot_slo: float = 0.040,
                tenant: Optional[str] = None) -> "Workload":
        rng = np.random.default_rng(seed)
        t = cls.poisson_arrivals(n, qps, rng)
        if tenant is None:
            return cls([(float(tt), in_tokens, out_tokens, ttft_slo,
                         tpot_slo) for tt in t], name="uniform")
        return cls([(float(tt), in_tokens, out_tokens, ttft_slo, tpot_slo,
                     tenant) for tt in t], name=f"uniform:{tenant}")

    @classmethod
    def sessions(cls, n_sessions: int, turns: int, qps: float, tenant: str,
                 seed: int = 0, system_tokens: int = 512,
                 turn_tokens: int = 256, out_tokens: int = 128,
                 think_s: float = 2.0, ttft_slo: float = 1.0,
                 tpot_slo: float = 0.040) -> "Workload":
        """Multi-turn agentic sessions: every turn re-sends the whole
        conversation (shared system prompt + all prior turns), so turn k
        carries ``system_tokens + (k+1)*turn_tokens`` input tokens of which
        all but the newest turn are prefix-cacheable. Session starts are
        Poisson at ``qps``; turns within a session are spaced by
        exponential think times."""
        rng = np.random.default_rng(seed)
        starts = cls.poisson_arrivals(n_sessions, qps, rng)
        think = rng.exponential(think_s, (n_sessions, turns))
        entries: List[tuple] = []
        for j in range(n_sessions):
            t = float(starts[j])
            path = ["sys:" + tenant]
            segs = [system_tokens]
            for k in range(turns):
                if k:
                    t = t + float(think[j, k])
                path.append(f"s{j}.t{k}")
                segs.append(turn_tokens)
                entries.append((t, system_tokens + (k + 1) * turn_tokens,
                                out_tokens, ttft_slo, tpot_slo, tenant,
                                (tuple(path), tuple(segs))))
        return cls(entries, name=f"sessions:{tenant}")

    @classmethod
    def phased_mix(cls, workloads: List["Workload"],
                   name: str = "mix") -> "Workload":
        """Concatenate workloads end-to-end in arrival time (each phase's
        arrivals are offset by the previous phase's last arrival) — the
        fleet-scale scenario's mixed longbench/sonnet arrival phases."""
        entries, offset = [], 0.0
        for wl in workloads:
            last = 0.0
            for e in wl.entries:
                entries.append((e[0] + offset,) + tuple(e[1:]))
                last = max(last, e[0])
            offset += last
        return cls(entries, name=name)


def build_request(rid: int, entry: tuple) -> SimRequest:
    """Construct a ``SimRequest`` (and its ``RequestRecord``) from one
    workload entry — the single decoding point shared by the single-node
    arrival seeder (``NodeSimulator.run``) and the cluster's
    (``ClusterSimulator._seed_arrivals``). Entries are
    ``(arrival, in_tokens, out_tokens, ttft_slo, tpot_slo)`` with optional
    trailing tenant name and ``(prefix_path, prefix_seg_tokens)`` pair."""
    t, it, ot, ts, ps = entry[:5]
    tenant = entry[5] if len(entry) > 5 else "default"
    rec = RequestRecord(rid, t, it, ot, ttft_slo=ts, tpot_slo=ps,
                        tenant=tenant)
    req = SimRequest(rec)
    if len(entry) > 6 and entry[6] is not None:
        path, segs = entry[6]
        req.prefix_key = tuple(path)
        req.prefix_tokens = tuple(int(s) for s in segs)
    return req


class NodeSimulator:
    """One power-capped 8-GPU node. Owns its queues/roles/power manager;
    the *clock* is an ``EventLoop`` that may be private (single-node ``run``)
    or shared with sibling nodes by a cluster simulator (``core.cluster``)."""

    def __init__(self, cfg: ModelConfig, policy: StaticPolicy,
                 node_budget_w: float = 4800.0,
                 gpu: GPUSpec = MI300X, power: Optional[PowerModel] = None,
                 ctrl_cfg: Optional[ControllerConfig] = None,
                 coalesced: bool = False, seed: int = 0,
                 min_cap_w: Optional[float] = None,
                 max_cap_w: Optional[float] = None,
                 loop: Optional[EventLoop] = None, node_id: int = 0,
                 fidelity: str = "macro", sanitize: Optional[bool] = None,
                 cache_cfg: Optional[PrefixCacheConfig] = None,
                 tenancy: Optional[TenantRegistry] = None):
        assert fidelity in ("macro", "iter"), fidelity
        self.fidelity = fidelity
        self._macro = fidelity == "macro"
        self.node_id = node_id
        # power curves and the cap range both default from the GPU spec, so a
        # heterogeneous cluster gets per-node envelopes without extra plumbing
        self.cost = CostModel(cfg, gpu, power or get_power_model(gpu.power))
        self.n_gpus = policy.n_prefill + policy.n_decode
        lo = min_cap_w if min_cap_w is not None else gpu.min_cap_w
        hi = max_cap_w if max_cap_w is not None else gpu.max_cap_w
        # clamp the policy's caps to THIS node's spec envelope before the
        # budget check: one cluster-wide StaticPolicy then lands correctly
        # on every spec (a 500 W split becomes 200 W caps on a TPU-v5e node)
        caps = [min(max(c, lo), hi) for c in policy.caps()]
        assert sum(caps) <= node_budget_w + 1e-6, (caps, node_budget_w)
        self.pm = PowerManager(self.n_gpus, node_budget_w, initial_caps=caps,
                               min_cap=lo, max_cap=hi, sanitize=sanitize)
        self.coalesced = coalesced
        if coalesced:
            self.gpus = [GPU(i, "mixed") for i in range(self.n_gpus)]
        else:
            self.gpus = ([GPU(i, "prefill") for i in range(policy.n_prefill)] +
                         [GPU(policy.n_prefill + i, "decode")
                          for i in range(policy.n_decode)])
        self.ctrl = (RapidController(ctrl_cfg, self.pm) if ctrl_cfg else None)
        self.ctrl_cfg = ctrl_cfg
        self.rng = np.random.default_rng(seed)
        # multi-tenancy + session locality (core.tenancy, core.prefixcache):
        # both default off, and every touch point below is None-gated, so
        # single-stream runs keep their exact pre-tenancy event sequence
        self.tenancy = tenancy
        self.cache_cfg = cache_cfg
        if cache_cfg is not None:
            free = max(0.85 * gpu.hbm_bytes - self.cost.weight_bytes(), 0.0)
            cap_toks = int(cache_cfg.frac * self.n_gpus * free
                           / self.cost.kv_bytes_per_token())
            self.prefix_cache: Optional[PrefixCache] = \
                PrefixCache(node_id, cap_toks)
        else:
            self.prefix_cache = None
        self.preempt_trace: List[tuple] = []  # (t, rid, gid, victim rids)
        self.prefix_hit_tokens = 0            # cached tokens actually reused

        if loop is not None:
            # shared clock: the cluster layer owns the loop (and any
            # sanitizer attached to it); a per-node flag would fragment
            # the facility-level invariant checks
            self.loop = loop
        else:
            self.loop = EventLoop()
            if sanitize_enabled(sanitize):
                san = InvariantSanitizer()
                san.attach_node(self)
                self.loop.sanitizer = san
        self.q_prefill: deque = deque()
        self.q_prefill_tokens = 0               # incremental token sum
        self.ring_free = RING_SLOTS
        self.ring_wait: deque = deque()
        self.records: List[RequestRecord] = []
        self.recent_ttft = MetricWindow()       # per-request TTFT samples
        self.recent_tpot = MetricWindow()       # decode iteration times
        self.recent_req_tpot = MetricWindow()   # completed-request TPOT
        self.power_samples: List[tuple] = []    # (t, provisioned, roles)
        self.trace_caps: List[tuple] = []       # (t, caps per gpu, roles)
        self.mixed_rr = 0
        self.finished_count = 0    # O(1) termination checks for the loop
        self.decode_iters = 0      # simulated decode iterations (perf metric)
        self._ext_flip_gids: set = set()   # coordinator-requested drains
        # incremental sums over ALL active decode requests on this node
        self._g_ctx_sum = 0
        self._g_ctx_n = 0
        # sync fast path: earliest unmaterialized plan end on this node
        # (lower bound — recomputed on every full scan) and the last seen
        # power-manager aggregate version (plans need revalidation only
        # when it moves)
        self._next_due = math.inf
        self._capv_seen = 0
        # role/drain transition counter + capacity cache for the router
        self._role_version = 0
        self._cap_tps_cache = None
        # fleet hooks (core.fleet): ``migrator(reqs, node, has_kv, reason)``
        # receives requests this node can no longer serve; ``leaving`` makes
        # completed prefills / KV transfers hand off instead of staying;
        # ``defunct`` (failed/left) drops every subsequent event.
        self.migrator = None
        self.leaving = False
        self.defunct = False
        # in-flight ring KV transfers (insertion-ordered for determinism);
        # requests here hold a ring slot and exist only in event payloads
        self._transfers: Dict[SimRequest, None] = {}
        # records handed to another node (migration/requeue) stay in the
        # list — eviction storms must not pay O(records) per request — and
        # are filtered out lazily at summary time
        self._released_rids: set = set()

    # ---------------- event plumbing ----------------
    @property
    def now(self) -> float:
        return self.loop.now

    def _push(self, t: float, kind: str, payload=None):
        self.loop.push(t, self.handle, kind, payload)

    # ---------------- role lists ----------------
    def prefill_gpus(self) -> List[int]:
        return [g.gid for g in self.gpus if g.role == "prefill"
                and not g.draining]

    def decode_gpus(self) -> List[int]:
        return [g.gid for g in self.gpus if g.role == "decode"
                and not g.draining]

    # ---------------- prefill ----------------
    def _kick_prefill(self, gpu: GPU):
        if gpu.busy or gpu.draining or self.leaving or not self.q_prefill:
            return
        batch, tokens = [], 0
        while (self.q_prefill and len(batch) < MAX_PREFILL_BATCH_REQS and
               tokens < MAX_PREFILL_BATCH_TOKENS):
            nxt = self.q_prefill[0]
            if batch and tokens + nxt.rec.input_tokens > MAX_PREFILL_BATCH_TOKENS:
                break
            self.q_prefill.popleft()
            self.q_prefill_tokens -= nxt.rec.input_tokens
            batch.append(nxt)
            tokens += nxt.rec.input_tokens
        if not batch:
            return
        gpu.busy = True
        gpu.inflight_prefill = batch
        cap = self.pm.effective[gpu.gid]
        if self.prefix_cache is None:
            dt = self.cost.prefill_time(tokens, cap)
            # batch energy attributed proportionally by prompt tokens
            # (charged up front: if the node fails mid-batch the joules
            # were still spent)
            e_batch = self.cost.power.joules("prefill", cap, dt)
            for req in batch:
                req.rec.energy_j += e_batch * (req.rec.input_tokens / tokens)
        else:
            # session locality: each request prefills only the suffix its
            # resident prefix doesn't cover (at least one token — the new
            # turn always computes something). Lookup at batch launch is
            # the instant the reuse is physically realized, and it touches
            # LRU state, so macro/iter fire it at identical instants.
            eff = 0
            for req in batch:
                cached = 0
                if req.prefix_key:
                    cached = min(self.prefix_cache.lookup(req.prefix_key),
                                 req.rec.input_tokens - 1)
                req.cached_tokens = cached
                self.prefix_hit_tokens += cached
                eff += req.rec.input_tokens - cached
            eff = max(eff, 1)
            dt = self.cost.prefill_time(eff, cap)
            e_batch = self.cost.power.joules("prefill", cap, dt)
            for req in batch:
                req.rec.energy_j += e_batch * (
                    (req.rec.input_tokens - req.cached_tokens) / eff)
        self._push(self.now + dt, "prefill_done", (gpu.gid, batch))

    def _on_prefill_done(self, gid: int, batch: List[SimRequest]):
        gpu = self.gpus[gid]
        gpu.busy = False
        gpu.inflight_prefill = None
        if self.leaving and self.migrator is not None:
            # node is draining out of the fleet: the fresh KV leaves over
            # the node interconnect instead of entering the local ring
            for req in batch:
                req.rec.prefill_done = self.now
                self.recent_ttft.append(self.now, req.rec.ttft)
            self.migrator(batch, self, True, "leave")
            return
        for req in batch:
            req.rec.prefill_done = self.now
            self.recent_ttft.append(self.now, req.rec.ttft)
            if self.prefix_cache is not None and req.prefix_key:
                # the KV this prefill just produced becomes reusable prefix
                self.prefix_cache.insert(req.prefix_key, req.prefix_tokens)
            self._ring_enqueue(req)
        if gpu.draining:
            self._push(self.now + self._drain_s(), "drain_done", gid)
        else:
            self._kick_prefill(gpu)

    # ---------------- KV ring buffer ----------------
    def _ring_enqueue(self, req: SimRequest):
        self.ring_wait.append(req)
        self._ring_pump()

    def _ring_pump(self):
        while self.ring_free > 0 and self.ring_wait:
            req = self.ring_wait.popleft()
            self.ring_free -= 1
            self._transfers[req] = None
            dt = self.cost.kv_transfer_time(req.rec.input_tokens)
            self._push(self.now + dt, "transfer_done", req)

    def _on_transfer_done(self, req: SimRequest):
        if self.migrator is not None and (self.leaving
                                          or not self.decode_gpus()):
            # node is leaving, or carries no live decode role at all (it
            # went full-prefill under a fleet role flip): the KV leaves
            # cross-node instead of joining a local batch
            self._transfers.pop(req, None)
            self.ring_free += 1
            self._ring_pump()
            self.migrator([req], self, True,
                          "leave" if self.leaving else "no_decode_role")
            return
        dgpus = self.decode_gpus() or [g.gid for g in self.gpus
                                       if g.role == "decode"]
        def load(i: int) -> int:
            return len(self.gpus[i].active) + len(self.gpus[i].pending_join)
        cap = self.cost.max_decode_batch(int(self._global_avg_ctx()))
        if not dgpus or min((load(i) for i in dgpus), default=cap) >= cap:
            if not self._maybe_preempt(req, dgpus):
                # decode pool saturated: request stays in its ring slot
                # (backpressure on prefill, paper Section 3.3)
                self._push(self.now + 0.02, "transfer_done", req)
                return
            # a batch was evicted for this request: fall through to
            # placement — ``load`` re-reads the now-freed GPU
        self._transfers.pop(req, None)
        self.ring_free += 1
        self._ring_pump()
        gid = min(dgpus, key=load)
        req.decode_gpu = gid
        gpu = self.gpus[gid]
        gpu.pending_join.append(req)
        self._kick_decode(gpu)

    def _maybe_preempt(self, req: SimRequest, dgpus: List[int]) -> bool:
        """Priority preemption (core.tenancy): when the decode pool is
        saturated, an arriving request whose tenant strictly out-ranks
        EVERY member of some decode batch evicts that batch back through
        the requeue path (fleet router when attached, else the local
        prefill queue — never a silent drop) and takes the freed GPU.
        Victim choice is deterministic: lowest batch-max priority, then
        smallest batch, then lowest gid. The eviction reuses the exact
        fold/truncate machinery of drain migrations, so macro and iter
        fidelities preempt at the same instant with identical state."""
        ten = self.tenancy
        if ten is None or not ten.preempt or not dgpus:
            return False
        pri = ten.priority(req.rec.tenant)
        best = None
        for i in dgpus:
            g = self.gpus[i]
            members = g.active + g.pending_join
            if not members:
                continue
            top = max(ten.priority(r.rec.tenant) for r in members)
            if top >= pri:
                continue
            key = (top, len(members), i)
            if best is None or key < best[0]:
                best = (key, g)
        if best is None:
            return False
        gpu = best[1]
        victims = self.evict_decode_batch(gpu)
        self.preempt_trace.append((self.now, req.rec.rid, gpu.gid,
                                   tuple(v.rec.rid for v in victims)))
        for v in victims:
            # KV and generated tokens are dropped; spent joules stay billed
            v.reset_for_requeue()
        if self.migrator is not None:
            # re-enters through router admission (which may shed it) —
            # the sanitizer's no-silent-drop check tracks these rids
            self.migrator(victims, self, False, "preempt")
        else:
            for v in victims:
                self.q_prefill.append(v)
                self.q_prefill_tokens += v.rec.input_tokens
            for gid in self.prefill_gpus():
                self._kick_prefill(self.gpus[gid])
        return True

    def _global_avg_ctx(self) -> float:
        if not self._g_ctx_n:
            return 1000.0
        return self._g_ctx_sum / self._g_ctx_n

    # ---------------- decode ----------------
    def _avg_ctx(self, gpu: GPU) -> float:
        if not gpu.active:
            return 1.0
        return gpu.ctx_sum / len(gpu.active)

    def _merge_pending(self, gpu: GPU):
        if not gpu.pending_join:
            return
        epoch = gpu.tok_epoch
        e_epoch = gpu.energy_epoch
        for r in gpu.pending_join:
            r.tok_mark = epoch     # tokens_out is exact for an off-GPU req
            r.e_mark = e_epoch
            ctx = r.rec.input_tokens + r.tokens_out
            gpu.ctx_sum += ctx
            self._g_ctx_sum += ctx
        self._g_ctx_n += len(gpu.pending_join)
        gpu.active.extend(gpu.pending_join)
        gpu.pending_join.clear()

    @staticmethod
    def _fold(gpu: GPU, r: SimRequest) -> int:
        """Fold the GPU's epoch deltas into the request's exact token count
        and spent energy (the request is finishing or leaving this GPU)."""
        r.tokens_out += gpu.tok_epoch - r.tok_mark
        r.tok_mark = gpu.tok_epoch
        r.rec.energy_j += gpu.energy_epoch - r.e_mark
        r.e_mark = gpu.energy_epoch
        return r.tokens_out

    def _remove_finished(self, gpu: GPU):
        keep = []
        for r in gpu.active:
            if r.rec.finish is None:
                keep.append(r)
            else:
                ctx = r.rec.input_tokens + r.tokens_out
                gpu.ctx_sum -= ctx
                self._g_ctx_sum -= ctx
                self._g_ctx_n -= 1
        gpu.active = keep

    def _kick_decode(self, gpu: GPU):
        if gpu.iterating:
            # a join arriving mid-plan must merge at the end of the
            # in-flight iteration, exactly where the per-iteration path
            # would next merge: cut the plan short there
            if gpu.plan is not None and gpu.pending_join:
                self._truncate_plan(gpu, self.now)
            return
        self._merge_pending(gpu)
        if not gpu.active:
            return
        gpu.iterating = True
        cap = self.pm.effective[gpu.gid]
        if self._macro:
            self._start_macro(gpu, cap)
        else:
            b = len(gpu.active)
            dt = self.cost.decode_step_time(b, self._avg_ctx(gpu), cap)
            de = self.cost.power.draw("decode", cap, True) * dt / b
            self._push(self.now + dt, "decode_iter", (gpu.gid, dt, de))

    def _on_decode_iter(self, gid: int, dt: float, de: float):
        gpu = self.gpus[gid]
        gpu.iterating = False
        gpu.energy_epoch = gpu.energy_epoch + de
        e_epoch = gpu.energy_epoch
        self.recent_tpot.append(self.now, dt)
        self.decode_iters += 1
        done_any = False
        for r in gpu.active:
            r.tokens_out += 1
            if r.tokens_out >= r.rec.output_tokens:
                r.rec.finish = self.now
                r.rec.energy_j += e_epoch - r.e_mark
                r.e_mark = e_epoch
                self.finished_count += 1
                self.recent_req_tpot.append(self.now, r.rec.tpot)
                done_any = True
        nb = len(gpu.active)
        gpu.ctx_sum += nb
        self._g_ctx_sum += nb
        if done_any:
            self._remove_finished(gpu)
        if gpu.draining and not gpu.active:
            self._push(self.now + self._drain_s(), "drain_done", gid)
            return
        self._kick_decode(gpu)

    # ---------------- macro-stepping ----------------
    def _start_macro(self, gpu: GPU, cap: float):
        """Plan the run of decode iterations from now to the next intrinsic
        boundary (first request completion, pending cap-change effective
        time, or the chunk limit) and schedule one event at its end."""
        b = len(gpu.active)
        epoch = gpu.tok_epoch
        k = min(r.rec.output_tokens - r.tokens_out - epoch + r.tok_mark
                for r in gpu.active)
        # capping below the first finish is sound: a plan end with no
        # finishing request simply re-plans — an iteration boundary, exactly
        # where the per-iteration path re-reads the world anyway
        k = min(max(k, 1), gpu.k_hint, MACRO_CHUNK)
        e_cap = math.inf               # earliest pending cap change, this GPU
        for ch in self.pm.pending:
            if ch.gpu == gpu.gid and ch.effective_at < e_cap:
                e_cap = ch.effective_at
        t0 = self.now
        cost = self.cost
        # per-iteration times, float-identical to decode_step_time(): the
        # context mean advances by exactly one token per iteration and
        # (ctx_sum + i*b)/b is the same correctly-rounded float np.mean
        # produced from the active list. End times accumulate sequentially
        # — the same float chain as scheduling each iteration off the
        # previous event's timestamp.
        weight = cost._weight_bytes
        kv_per = cost._kv_per_token
        bw = cost._decode_bw
        floor = 2.0 * cost._active_params * max(b, 1) / cost._prefill_flops_s
        rel = cost.rel("decode", cap)
        oh = cost.gpu.overhead_decode_s
        draw = cost.power.draw("decode", cap, True)
        if k <= 24:
            # scalar path: numpy's fixed per-op overhead loses at short k
            # (IEEE float64 ops are identical either way)
            dts = []
            ends = []
            e_ends = []
            t = t0
            e = gpu.energy_epoch
            ctx = gpu.ctx_sum
            for _ in range(k):
                base = (weight + kv_per * (ctx / b) * b) / bw
                if base < floor:
                    base = floor
                dt = base / rel + oh
                dts.append(dt)
                t = t + dt
                ends.append(t)
                e = e + draw * dt / b
                e_ends.append(e)
                ctx += b
                if t >= e_cap and len(ends) < k:
                    break
            end_arr = np.array(ends)
            dt_arr = np.array(dts)
            e_arr = np.array(e_ends)
        else:
            ctx0 = gpu.ctx_sum
            # np.arange with step b enumerates ctx0 + i*b exactly (int64)
            avg = np.arange(ctx0, ctx0 + k * b, b, dtype=np.int64) / b
            base = (weight + kv_per * avg * b) / bw
            np.maximum(base, floor, out=base)
            dt_arr = base / rel + oh
            # ufunc accumulate is a sequential left fold, so seeding it
            # with t0 reproduces bit-for-bit the (t += dt) chain of
            # per-event scheduling (property-tested in the macrostep tests)
            acc = np.empty(k + 1)
            acc[0] = t0
            acc[1:] = dt_arr
            end_arr = np.cumsum(acc, out=acc)[1:]
            # same left-fold trick for the energy epochs: elementwise
            # (draw*dt)/b matches the per-iteration path's float ops, and
            # the seeded cumsum matches its sequential accumulation
            eacc = np.empty(k + 1)
            eacc[0] = gpu.energy_epoch
            eacc[1:] = draw * dt_arr / b
            e_arr = np.cumsum(eacc, out=eacc)[1:]
            if e_cap is not math.inf and end_arr[-1] >= e_cap:
                # keep iterations starting before the cap change: the first
                # end >= e_cap is the last valid iteration's boundary
                n = int(end_arr.searchsorted(e_cap, side="left")) + 1
                end_arr = end_arr[:n]
                dt_arr = dt_arr[:n]
                e_arr = e_arr[:n]
        gpu.gen += 1
        gpu.plan = MacroPlan(gen=gpu.gen, end_times=end_arr, dts=dt_arr,
                             e_ends=e_arr, capv=self.pm.cap_version[gpu.gid])
        first = end_arr[0]
        if first < self._next_due:
            self._next_due = first
        self._push(float(end_arr[-1]), "macro_done", (gpu.gid, gpu.gen))

    def _materialize(self, gpu: GPU, upto: int) -> float:
        """Write iterations [plan.m, upto) into simulator state: the GPU
        token epoch (O(1) for the whole batch), context sums, and
        TPOT-window entries. Returns the last materialized end time."""
        p = gpu.plan
        m = p.m
        delta = upto - m
        gpu.tok_epoch += delta
        gpu.energy_epoch = float(p.e_ends[upto - 1])
        nb = len(gpu.active)
        if nb:
            add = delta * nb
            gpu.ctx_sum += add
            self._g_ctx_sum += add
        ends, dts = p.end_times, p.dts
        self.recent_tpot.extend(ends[m:upto], dts[m:upto])
        self.decode_iters += delta
        p.m = upto
        return ends[upto - 1]

    def sync_power(self) -> None:
        """Router-read fidelity on cluster arrivals: the per-iteration path
        applies pending cap changes at every decode-iteration event, so a
        cross-node read between an enforcement instant and the next real
        node event must see the updated caps. With no change in flight
        (almost always — enforcement windows last 0.3 s after a controller
        action) the tick is a no-op and this is O(1); otherwise run a full
        sync, which ticks the power manager to the last elapsed iteration
        end exactly as the per-iteration path would have."""
        if self.pm.pending:
            self.sync()

    def sync(self) -> None:
        """Materialize all macro iterations that completed strictly before
        the current event's timestamp, then bring the power manager up to
        the last materialized instant (the per-iteration path would have
        ticked it at each of those iteration-end events). ``_next_due`` is a
        lower bound on the earliest unmaterialized end, making the common
        nothing-elapsed case a single comparison."""
        now = self.loop.now
        if now <= self._next_due:
            return
        last = 0.0
        nxt = math.inf
        for gpu in self.gpus:
            p = gpu.plan
            if p is None:
                continue
            ends = p.end_times
            m = p.m
            if m < len(ends) and ends[m] < now:
                m += int(ends[m:].searchsorted(now, side="left"))
                end = ends[m - 1]
                self._materialize(gpu, m)
                if end > last:
                    last = end
            if m < len(ends) and ends[m] < nxt:
                nxt = ends[m]
        self._next_due = nxt
        if last:
            self.pm.tick(last)

    def _truncate_plan(self, gpu: GPU, t: float):
        """Cut a running plan at the end of the iteration in flight at time
        ``t`` (an intrinsic boundary for the per-iteration path) and
        re-schedule its completion event there."""
        p = gpu.plan
        m = p.m
        j = m + int(p.end_times[m:].searchsorted(t, side="left"))
        if j >= len(p.end_times) - 1:
            return                 # already ends at the in-flight boundary
        p.end_times = p.end_times[:j + 1]    # O(1) views
        p.dts = p.dts[:j + 1]
        p.e_ends = p.e_ends[:j + 1]
        gpu.gen += 1
        p.gen = gpu.gen
        self._push(float(p.end_times[j]), "macro_done", (gpu.gid, gpu.gen))

    def _validate_plans(self):
        """Post-event check: any cap command/application on a GPU since its
        plan was laid invalidates the not-yet-started iterations — truncate
        at the in-flight boundary so the next plan re-reads fresh caps."""
        if self.pm.version_total == self._capv_seen:
            return
        self._capv_seen = self.pm.version_total
        capv = self.pm.cap_version
        for gpu in self.gpus:
            p = gpu.plan
            if p is not None and p.capv != capv[gpu.gid]:
                p.capv = capv[gpu.gid]
                self._truncate_plan(gpu, self.loop.now)

    def _on_macro_done(self, gid: int, gen: int):
        gpu = self.gpus[gid]
        p = gpu.plan
        if p is None or gen != p.gen:
            return                 # superseded by a truncation/cancellation
        if p.m < len(p.end_times):
            self._materialize(gpu, len(p.end_times))
        gpu.k_hint = min(max(4 * p.m, 64), MACRO_CHUNK)
        gpu.plan = None
        gpu.iterating = False
        done_any = False
        epoch = gpu.tok_epoch
        e_epoch = gpu.energy_epoch
        for r in gpu.active:
            tok = r.tokens_out + epoch - r.tok_mark   # inlined token fold
            r.tokens_out = tok
            r.tok_mark = epoch
            if tok >= r.rec.output_tokens:
                r.rec.finish = self.now
                # energy folds ONLY at finish/leave (not at plan
                # boundaries), mirroring the per-iteration path's fold
                # instants so the float sums agree exactly
                r.rec.energy_j += e_epoch - r.e_mark
                r.e_mark = e_epoch
                self.finished_count += 1
                self.recent_req_tpot.append(self.now, r.rec.tpot)
                done_any = True
        if done_any:
            self._remove_finished(gpu)
        if gpu.draining and not gpu.active:
            self._push(self.now + self._drain_s(), "drain_done", gid)
            return
        self._kick_decode(gpu)

    # ---------------- coalesced (chunked prefill, Sarathi-style) ----------
    def _kick_mixed(self, gpu: GPU):
        if gpu.iterating:
            return
        self._merge_pending(gpu)
        if not gpu.mixed_prefill and not gpu.active:
            return
        gpu.iterating = True
        cap = self.pm.effective[gpu.gid]
        if gpu.mixed_prefill:
            req, done_toks = gpu.mixed_prefill[0]
            chunk = min(PREFILL_CHUNK, req.rec.input_tokens - done_toks)
            dt = self.cost.prefill_time(chunk, cap) * CHUNK_PENALTY
            if gpu.active:   # decode KV traffic rides the fused iteration
                dt += (self.cost.kv_bytes_per_token() * self._avg_ctx(gpu) *
                       len(gpu.active)) / (self.cost.gpu.hbm_bw *
                                           self.cost.gpu.mbu_decode)
            # fused-iteration energy split evenly across participants
            # (chunk owner + riding decoders); charged on completion
            de = (self.cost.power.joules("prefill", cap, dt)
                  / (1 + len(gpu.active)))
            self._push(self.now + dt, "mixed_iter", (gpu.gid, dt, chunk, de))
        else:
            b = len(gpu.active)
            dt = self.cost.decode_step_time(b, self._avg_ctx(gpu), cap)
            de = self.cost.power.joules("decode", cap, dt) / b
            self._push(self.now + dt, "mixed_iter", (gpu.gid, dt, 0, de))

    def _on_mixed_iter(self, gid: int, dt: float, chunk: int, de: float):
        gpu = self.gpus[gid]
        gpu.iterating = False
        if chunk and gpu.mixed_prefill:
            req, done_toks = gpu.mixed_prefill.popleft()
            req.rec.energy_j += de
            done_toks += chunk
            if done_toks >= req.rec.input_tokens:
                req.rec.prefill_done = self.now
                self.recent_ttft.append(self.now, req.rec.ttft)
                gpu.pending_join.append(req)   # same GPU continues decoding
            else:
                gpu.mixed_prefill.appendleft((req, done_toks))
        if gpu.active:
            self.recent_tpot.append(self.now, dt)
            self.decode_iters += 1
            done_any = False
            for r in gpu.active:
                r.tokens_out += 1
                r.rec.energy_j += de
                if r.tokens_out >= r.rec.output_tokens:
                    r.rec.finish = self.now
                    self.finished_count += 1
                    done_any = True
            nb = len(gpu.active)
            gpu.ctx_sum += nb
            self._g_ctx_sum += nb
            if done_any:
                self._remove_finished(gpu)
        self._kick_mixed(gpu)

    # ---------------- controller ----------------
    def _window_p90(self, win: MetricWindow) -> float:
        return win.p90(self.now - METRIC_WINDOW_S)

    def _queue_ttft_estimate(self) -> float:
        """Pessimistic TTFT signal from queue head age (early warning)."""
        if not self.q_prefill:
            return 0.0
        head = self.q_prefill[0]
        return self.now - head.rec.arrival

    def _drain_s(self) -> float:
        return (self.ctrl_cfg.gpu_move_drain_s if self.ctrl_cfg else 3.0)

    def _on_ctrl(self):
        if not self.pm.powered:
            # powered off (standby / left the fleet): no sampling and no
            # re-arm — a fleet join calls ``start()`` to resume the tick
            return
        self.pm.tick(self.now)
        self.trace_caps.append((self.now, list(self.pm.effective),
                                [g.role for g in self.gpus]))
        self.power_samples.append((self.now, sum(self.pm.effective)))
        # liveness heartbeat on the shared loop: the fleet's failure
        # detector (core.telemetry.HeartbeatDetector) infers alive/
        # suspected/dead from these — a dead or powered-off node simply
        # stops publishing (the powered gate above kills the re-arm)
        self.loop.publish("heartbeat", self.node_id)
        if self.ctrl is not None and not self.coalesced:
            obs = self.observe()
            pre, dec = self.prefill_gpus(), self.decode_gpus()
            d = self.ctrl.tick(obs, pre, dec)
            if d.kind == "power":
                src, dst = (dec, pre) if d.direction == "d2p" else (pre, dec)
                dst_max = (self.ctrl_cfg.decode_cap_max_w
                           if d.direction == "p2d" else self.pm.max_cap)
                # lower each source by one step; never below min
                t_ready, freed = self.pm.shift(self.now, src, dst,
                                               self.ctrl_cfg.power_step_w)
                # sink raise after sources enforced; payload rides the event
                self._push(t_ready, "power_ready", (list(dst), freed, dst_max))
            elif d.kind == "gpu":
                self._start_role_switch(d.direction)
        if self.loop.heap:
            self._push(self.now + (self.ctrl_cfg.min_time_s
                                   if self.ctrl_cfg else 0.25), "ctrl")

    def can_flip(self, direction: str, allow_empty: bool = False) -> bool:
        """Whether a role flip in ``direction`` would leave the node with at
        least the configured minimum of source-role GPUs. ``allow_empty``
        (fleet-managed nodes only, d2p) lets the LAST decode GPU flip: its
        batch migrates cross-node through the fleet's migration engine, and
        later prefill completions route their KV out the same way."""
        if self.coalesced:
            return False
        if direction == "d2p":
            floor = (0 if allow_empty and self.migrator is not None
                     else (self.ctrl_cfg.min_decode_gpus
                           if self.ctrl_cfg else 1))
            return len(self.decode_gpus()) > floor
        return len(self.prefill_gpus()) > (self.ctrl_cfg.min_prefill_gpus
                                           if self.ctrl_cfg else 1)

    def request_role_flip(self, direction: str) -> bool:
        """Externally-requested MoveGPU (cluster coordinator): start draining
        one GPU toward the opposite role. Same drain discipline as the node
        controller's own GPU moves; completion is announced on the shared
        loop as a ``role_flip`` event with ``external=True`` so the
        coordinator can tell its own flips from the node controller's.
        With a fleet migrator attached, a d2p flip may take the node's last
        decode GPU (pinned-only traffic: its decode work leaves cross-node).
        Returns False if refused (coalesced node or at the role minimum)."""
        allow_empty = direction == "d2p"
        if not self.can_flip(direction, allow_empty=allow_empty):
            return False
        floor = (0 if allow_empty and self.migrator is not None else None)
        gid = self._start_role_switch(direction, floor=floor)
        if gid is None:
            return False
        self._ext_flip_gids.add(gid)
        return True

    def _start_role_switch(self, direction: str,
                           floor: Optional[int] = None) -> Optional[int]:
        """Pick and drain one GPU toward the opposite role; returns its gid
        (or None if refused at the role minimum — ``floor`` overrides the
        configured minimum for fleet-requested flips)."""
        if direction == "d2p":
            cands = self.decode_gpus()
            limit = floor if floor is not None else \
                (self.ctrl_cfg.min_decode_gpus if self.ctrl_cfg else 1)
            if len(cands) <= limit:
                return None
            gid = min(cands, key=lambda i: len(self.gpus[i].active))
            gpu = self.gpus[gid]
            gpu.draining = True
            self._role_version += 1
            # migrate its active requests (and not-yet-merged joins — they
            # would otherwise strand when consecutive drains leave no
            # iteration to merge them) to remaining decode GPUs
            others = [i for i in self.decode_gpus() if i != gid]
            if others and (gpu.active or gpu.pending_join):
                # the fold/truncate bookkeeping is the in-flight-boundary
                # eviction; placement is least-loaded like a fresh join
                self._place_on_decode(self.evict_decode_batch(gpu), others)
            elif self.migrator is not None and (gpu.active or
                                                gpu.pending_join):
                # last decode GPU on the node: the batch (and any not-yet-
                # merged joins) leaves over the node interconnect
                self.migrator(self.evict_decode_batch(gpu), self, True,
                              "role_flip")
            self._push(self.now + self._drain_s(), "drain_done", gid)
        else:
            cands = self.prefill_gpus()
            if len(cands) <= (self.ctrl_cfg.min_prefill_gpus
                              if self.ctrl_cfg else 1):
                return None
            gid = min(cands, key=lambda i: self.gpus[i].busy)
            gpu = self.gpus[gid]
            gpu.draining = True
            self._role_version += 1
            if not gpu.busy:
                self._push(self.now + self._drain_s(), "drain_done", gid)
            # else drain scheduled on prefill completion
        return gid

    def _on_drain_done(self, gid: int):
        gpu = self.gpus[gid]
        if not gpu.draining:      # duplicate drain event (already flipped)
            return
        gpu.draining = False
        gpu.role = "prefill" if gpu.role == "decode" else "decode"
        self._role_version += 1
        if gpu.role == "prefill" and (gpu.active or gpu.pending_join):
            # decode work landed on (or merged into) the GPU mid-drain —
            # re-place it now that the role actually flips: intra-node if a
            # decode GPU remains, else cross-node through the fleet
            others = self.decode_gpus()
            if others:
                self._place_on_decode(self.evict_decode_batch(gpu), others)
            elif self.migrator is not None:
                self.migrator(self.evict_decode_batch(gpu), self, True,
                              "role_flip")
        # Algorithm 1 line 14: uniform power after a GPU move
        t_ready, gpus, per = self.pm.distribute_uniform(self.now)
        self._push(t_ready, "uniform_ready", (gpus, per))
        # announce the completed flip (cluster coordinator, if any, clears
        # its in-flight tracking and re-asserts the facility invariant);
        # external=True iff this drain was coordinator-requested, so its
        # completion is never confused with a node-controller flip
        external = gid in self._ext_flip_gids
        self._ext_flip_gids.discard(gid)
        self.loop.publish("role_flip", (self.node_id, gid, gpu.role,
                                        external))
        if gpu.role == "prefill":
            self._kick_prefill(gpu)
        else:
            self._kick_decode(gpu)

    def _place_on_decode(self, reqs: List[SimRequest],
                         others: List[int]) -> None:
        """Re-place evicted decode requests on this node: each joins the
        currently least-loaded target (same policy as a fresh join), then
        every target is kicked once."""
        for r in reqs:
            tgt = min(others, key=lambda i: len(self.gpus[i].active))
            r.decode_gpu = tgt
            self.gpus[tgt].pending_join.append(r)
        for i in others:
            self._kick_decode(self.gpus[i])

    # ---------------- fleet-facing (churn + migration) ----------------
    def evict_decode_batch(self, gpu: GPU) -> List[SimRequest]:
        """Remove a decode GPU's whole batch (active + not-yet-merged joins)
        at the current iteration boundary, with exact token/energy folds and
        the same plan truncation an intra-node drain migration performs.
        The requests are the caller's (fleet migration engine) to place."""
        out = []
        for r in gpu.active:
            ctx = r.rec.input_tokens + self._fold(gpu, r)
            gpu.ctx_sum -= ctx
            self._g_ctx_sum -= ctx
            self._g_ctx_n -= 1
            r.decode_gpu = None
            out.append(r)
        gpu.active = []
        for r in gpu.pending_join:
            r.decode_gpu = None
            out.append(r)
        gpu.pending_join.clear()
        if gpu.plan is not None:
            self._truncate_plan(gpu, self.now)
        return out

    def evict_for_leave(self) -> None:
        """Graceful-leave eviction: everything movable right now. Returns
        ``(no_kv, with_kv)`` — queued prefill work (re-routes for free, its
        prompt was never processed) and KV-holding work (ring waiters +
        decode batches; moving it costs a cross-node KV transfer). In-flight
        prefill batches and ring transfers are NOT returned: their
        completion events hand off through the ``leaving`` hooks."""
        no_kv = list(self.q_prefill)
        self.q_prefill.clear()
        self.q_prefill_tokens = 0
        with_kv = list(self.ring_wait)
        self.ring_wait.clear()
        for gpu in self.gpus:
            if gpu.active or gpu.pending_join:
                with_kv.extend(self.evict_decode_batch(gpu))
        return no_kv, with_kv

    def evict_for_failure(self) -> List[SimRequest]:
        """Abrupt failure: every request the node holds, including those
        living only in event payloads (in-flight prefill batches, in-flight
        ring transfers). KV and generation progress are lost — the caller
        resets and re-submits them. The node is marked ``defunct`` and every
        subsequently dispatched event for it is dropped."""
        reqs = list(self.q_prefill) + list(self.ring_wait) + \
            list(self._transfers)
        self.q_prefill.clear()
        self.q_prefill_tokens = 0
        self.ring_wait.clear()
        self._transfers.clear()
        self.ring_free = RING_SLOTS
        for gpu in self.gpus:
            if gpu.inflight_prefill:
                reqs.extend(gpu.inflight_prefill)
                gpu.inflight_prefill = None
            for r in gpu.active:
                self._fold(gpu, r)       # joules spent are spent
                r.decode_gpu = None
                reqs.append(r)
            reqs.extend(gpu.pending_join)
            for r in gpu.pending_join:
                r.decode_gpu = None
            gpu.active = []
            gpu.pending_join.clear()
            gpu.mixed_prefill.clear()
            gpu.ctx_sum = 0
            gpu.plan = None
            gpu.gen += 1
            gpu.busy = False
            gpu.iterating = False
            gpu.draining = False
        self._g_ctx_sum = 0
        self._g_ctx_n = 0
        self._next_due = math.inf
        if self.prefix_cache is not None:
            self.prefix_cache.clear()    # cached KV dies with the HBM
        self.defunct = True
        return reqs

    def adopt_decode(self, req: SimRequest) -> bool:
        """Place a migrated-in request straight into the decode pool — its
        KV arrived over the node interconnect, so no ring slot is involved.
        Returns False when no live decode GPU has batch room (the fleet
        retries or re-targets)."""
        dgpus = self.decode_gpus()
        if not dgpus:
            return False
        def load(i: int) -> int:
            return len(self.gpus[i].active) + len(self.gpus[i].pending_join)
        gid = min(dgpus, key=load)
        if load(gid) >= self.cost.max_decode_batch(
                int(self._global_avg_ctx())):
            return False
        self._register(req)
        blk = req.carried_block
        if blk is not None:
            req.carried_block = None
            if self.prefix_cache is not None:
                # re-attach the migrated prefix leaf (only lands if its
                # parent prefix is already resident here — else it's lost
                # and the session's next turn recomputes it)
                self.prefix_cache.adopt(blk)
        req.decode_gpu = gid
        gpu = self.gpus[gid]
        gpu.pending_join.append(req)
        self._kick_decode(gpu)
        return True

    def is_empty(self) -> bool:
        """No request state left on the node (leave-drain completion)."""
        return (not self.q_prefill and not self.ring_wait
                and not self._transfers
                and all(not g.busy and not g.active and not g.pending_join
                        and not g.mixed_prefill for g in self.gpus))

    def release_record(self, req: SimRequest) -> None:
        """Hand a request's record over to whichever node it lands on next
        (kept one-node-exact so per-node summaries stay meaningful). O(1):
        the record stays in the list and summaries filter it out."""
        if req.preregistered:
            self._released_rids.add(req.rec.rid)
            req.preregistered = False

    def _register(self, req: SimRequest) -> None:
        if req.preregistered:
            return
        req.preregistered = True
        if req.rec.rid in self._released_rids:
            self._released_rids.discard(req.rec.rid)   # still in the list
        else:
            self.records.append(req.rec)

    # ---------------- cluster-facing signals ----------------
    def queued_prefill_tokens(self) -> int:
        toks = self.q_prefill_tokens
        if self.coalesced:
            toks += sum(max(req.rec.input_tokens - done, 0)
                        for g in self.gpus for req, done in g.mixed_prefill)
        return toks

    def prefill_capacity_tps(self) -> float:
        """Effective prefill-role capacity: aggregate token rate of the
        non-draining prefill GPUs at their *current* caps, through this
        node's own cost model — so a 4-GPU H100 pool and a 4-GPU MI300X pool
        report their real (different) rates, and a mid-drain role flip is
        reflected the moment the GPU leaves the role list. The rate is
        amortized over a full prefill batch so per-batch overhead is
        counted once, like the scheduler pays it.

        The router consults every node on every arrival; the value only
        changes with a cap change or a role/drain transition, so it is
        cached on (cap version, role version)."""
        key = (self.pm.version_total, self._role_version)
        cached = self._cap_tps_cache
        if cached is not None and cached[0] == key:
            return cached[1]
        pre = self.prefill_gpus() or [g.gid for g in self.gpus
                                      if not g.draining]
        tps = sum(
            MAX_PREFILL_BATCH_TOKENS /
            self.cost.prefill_time(MAX_PREFILL_BATCH_TOKENS,
                                   self.pm.effective[g])
            for g in pre)
        self._cap_tps_cache = (key, tps)
        return tps

    def queue_head_age(self) -> float:
        """Age of the oldest queued prefill request — the early-warning
        term of ``router_load``, exposed separately so the telemetry bus
        can snapshot the load signal's parts."""
        return self._queue_ttft_estimate()

    def router_load(self, extra_tokens: int = 0) -> float:
        """Power-adjusted load signal for the cluster router: estimated time
        to drain the queued prefill work (plus ``extra_tokens`` of the
        arriving request, making the signal a *marginal* cost) through this
        node's effective role capacity, plus the queue-head-age early
        warning (same signal the controller uses via
        ``_queue_ttft_estimate``)."""
        rate = self.prefill_capacity_tps()
        if rate <= 0.0:
            return float("inf")
        toks = self.queued_prefill_tokens() + extra_tokens
        return toks / rate + self._queue_ttft_estimate()

    def marginal_joules_per_token(self, in_tokens: int,
                                  out_tokens: int) -> float:
        """Marginal busy-draw energy price of serving one more request here:
        (prefill batch joules + out_tokens decode-iteration joules at the
        would-be batch size) / total tokens. The same power-curve/draw
        arithmetic the per-request energy accounting integrates, evaluated
        prospectively at the node's current caps and load — the signal the
        ``joules`` router policy ranks on. A node with no live decode role
        prices at infinity (its decode work would have to migrate out)."""
        pre = self.prefill_gpus()
        dec = self.decode_gpus()
        if not pre or not dec:
            return float("inf")
        power = self.cost.power
        cap_p = max(self.pm.effective[g] for g in pre)
        t_p = self.cost.prefill_time(in_tokens, cap_p)
        e_p = power.joules("prefill", cap_p, t_p)
        # marginal decode: joining the least-loaded decode GPU grows its
        # batch by one; the request pays a 1/b share of each iteration
        def load(i: int) -> int:
            return len(self.gpus[i].active) + len(self.gpus[i].pending_join)
        gid = min(dec, key=load)
        b = load(gid) + 1
        cap_d = self.pm.effective[gid]
        ctx = int(self._global_avg_ctx())
        dt_d = self.cost.decode_step_time(b, ctx, cap_d)
        e_tok = power.joules("decode", cap_d, dt_d) / b
        return (e_p + out_tokens * e_tok) / max(in_tokens + out_tokens, 1)

    def observe(self) -> Observation:
        """Current controller observation (also the coordinator's view —
        both MUST see the same metric definition)."""
        return Observation(
            now=self.now,
            ttft_p90=max(self._window_p90(self.recent_ttft),
                         self._queue_ttft_estimate()),
            tpot_p90=max(self._window_p90(self.recent_tpot),
                         self._window_p90(self.recent_req_tpot)),
            q_prefill=len(self.q_prefill),
            q_decode=(sum(len(g.pending_join) for g in self.gpus)
                      + len(self.ring_wait)),
        )

    def stress_summary(self) -> NodeStress:
        """SLO-relative stress for the cluster coordinator (works with or
        without a per-node controller)."""
        ttft_slo = self.ctrl_cfg.ttft_slo if self.ctrl_cfg else 1.0
        tpot_slo = self.ctrl_cfg.tpot_slo if self.ctrl_cfg else 0.040
        return stress_from(self.observe(), ttft_slo, tpot_slo,
                           node_id=self.node_id)

    # ---------------- main loop ----------------
    def submit(self, req: SimRequest) -> None:
        """Accept a request at the current time (called from the arrival
        event in single-node mode, or by the cluster router)."""
        assert not self.defunct and not self.leaving, \
            "submit() to a node that left the fleet"
        self._register(req)
        if self.tenancy is not None:
            self.tenancy.note_admit(req.rec.tenant)
        if self.coalesced:
            gpu = self.gpus[self.mixed_rr % self.n_gpus]
            self.mixed_rr += 1
            gpu.mixed_prefill.append((req, 0))
            self._kick_mixed(gpu)
        else:
            self.q_prefill.append(req)
            self.q_prefill_tokens += req.rec.input_tokens
            for gid in self.prefill_gpus():
                self._kick_prefill(self.gpus[gid])

    def start(self) -> None:
        """Schedule the periodic control/sampling tick."""
        self._push(self.loop.now, "ctrl")

    def n_unfinished(self) -> int:
        return len(self.records) - self.finished_count

    # Event kinds whose handlers read materialization-dependent state
    # (global context sums, TPOT windows, token epochs for drain folds).
    # The rest only touch queues, the ring, or the power manager — all
    # maintained eagerly — so skipping the sync both saves the scan and
    # coalesces materialization into fewer, larger runs. ``macro_done``
    # force-materializes its own plan inside the handler.
    _SYNC_KINDS = frozenset(("transfer_done", "ctrl", "drain_done"))

    def handle(self, kind: str, payload: Any = None) -> None:
        """Event sink: all node events dispatch through here. Macro fidelity
        first materializes any iterations that completed before this event
        (``sync``) when the handler can read iteration-dependent state, and
        afterwards re-validates running plans against cap changes the
        handler may have made."""
        if self.defunct:
            return    # failed node: in-flight events die with it
        if self._macro and kind in self._SYNC_KINDS:
            self.sync()
        self.pm.tick(self.now)
        if kind == "arrival":
            self.submit(payload)
        elif kind == "prefill_done":
            self._on_prefill_done(*payload)
        elif kind == "transfer_done":
            self._on_transfer_done(payload)
        elif kind == "decode_iter":
            self._on_decode_iter(*payload)
        elif kind == "macro_done":
            self._on_macro_done(*payload)
        elif kind == "mixed_iter":
            self._on_mixed_iter(*payload)
        elif kind == "ctrl":
            self._on_ctrl()
        elif kind == "power_ready":
            dst, freed, dst_max = payload
            self.pm.apply_raise(self.now, dst, freed, dst_max)
        elif kind == "uniform_ready":
            gpus, per = payload
            self.pm.apply_uniform(self.now, gpus, per)
        elif kind == "drain_done":
            self._on_drain_done(payload)
        else:
            raise ValueError(f"unknown event kind {kind!r}")
        if self._macro:
            self._validate_plans()

    def live_records(self) -> List[RequestRecord]:
        """Records still owned by this node (released ones filtered out)."""
        if not self._released_rids:
            return self.records
        return [r for r in self.records if r.rid not in self._released_rids]

    def summary(self) -> GoodputSummary:
        records = self.live_records()
        duration = max((r.finish or self.now) for r in records) if \
            records else self.now
        if self.power_samples:
            avg_w = float(np.mean(np.fromiter(
                (w for _, w in self.power_samples), dtype=np.float64)))
        else:
            avg_w = sum(self.pm.effective)
        return summarize(records, duration, avg_w)

    def run(self, workload: Workload, horizon_s: float = 1e5) -> GoodputSummary:
        """Single-node entry point: drives a private event loop to completion
        (cluster runs are driven by ``core.cluster.ClusterSimulator``).
        All records are registered upfront so a horizon-truncated run still
        counts never-arrived requests against SLO attainment. (Note: under
        macro fidelity a horizon-truncated run may stop the clock slightly
        earlier than per-iteration fidelity — completed-request records are
        identical, but ``duration_s`` of unfinished tails can differ.)"""
        for i, entry in enumerate(workload.entries):
            req = build_request(i, entry)
            req.preregistered = True
            self.records.append(req.rec)
            t = req.rec.arrival
            self._push(t, "arrival", req)
        self.start()
        self.loop.run(lambda: self.n_unfinished() == 0, horizon_s)
        return self.summary()
