"""Node power management: per-GPU caps under a fixed node budget, with the
paper's source-before-sink ordering for dynamic power shifting (Section 2.2).

``PowerBackend`` abstracts the enforcement mechanism (amd-smi on MI300X; a
platform power API or ILP duty-cycling on TPU). ``SimulatedSMI`` reproduces
the Fig 4c behaviour: a cap-lowering command takes ``enforce_latency_s`` to
take effect; raises are immediate (raising a cap cannot violate the budget
as long as the budget accounting uses commanded caps for raises and
*previous* caps for in-flight lowers — which is exactly what the paper's
"lower sources first, then raise sinks" rule guarantees).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.analysis.check.sanitize import InvariantViolation, sanitize_enabled

MIN_CAP_W = 400.0
MAX_CAP_W = 750.0


@dataclasses.dataclass
class CapChange:
    gpu: int
    watts: float
    effective_at: float


class PowerBackend:
    """Interface: schedule a cap change, report when it is in force."""

    def set_cap(self, now: float, gpu: int, watts: float) -> CapChange:
        raise NotImplementedError


class SimulatedSMI(PowerBackend):
    def __init__(self, enforce_latency_s: float = 0.3):
        self.enforce_latency_s = enforce_latency_s

    def set_cap(self, now: float, gpu: int, watts: float) -> CapChange:
        return CapChange(gpu, watts, now + self.enforce_latency_s)


class PowerManager:
    """Tracks commanded + effective caps for every GPU; enforces the node
    budget invariant sum(max(commanded, effective)) <= budget at all times.

    The budget itself is *mutable at runtime* (hierarchical power: a cluster
    coordinator moves watts between node budgets, ``core.cluster``) with the
    same source-before-sink discipline one level up: ``shrink_budget`` lowers
    GPU caps first and only ``commit_budget`` — once those caps are in force —
    actually releases the watts; ``grow_budget`` raises are immediate."""

    def __init__(self, n_gpus: int, node_budget_w: float,
                 backend: Optional[PowerBackend] = None,
                 min_cap: float = MIN_CAP_W, max_cap: float = MAX_CAP_W,
                 initial_caps: Optional[List[float]] = None,
                 sanitize: Optional[bool] = None):
        self.n = n_gpus
        # sanitizer mode: self-check the budget invariant after every
        # mutator, not only at the dispatch boundary (RAPID_SANITIZE=1)
        self.sanitize = sanitize_enabled(sanitize)
        self.budget = node_budget_w
        self._budget_target = node_budget_w   # < budget while a shrink is in flight
        self.backend = backend or SimulatedSMI()
        self.min_cap, self.max_cap = min_cap, max_cap
        caps = initial_caps or [node_budget_w / n_gpus] * n_gpus
        caps = [min(max(c, min_cap), max_cap) for c in caps]
        assert sum(caps) <= node_budget_w + 1e-6
        self.commanded: List[float] = list(caps)
        self.effective: List[float] = list(caps)
        self.pending: List[CapChange] = []
        self.history: List[tuple] = []     # (t, gpu, watts)
        self.budget_history: List[tuple] = []   # (t, budget)
        # per-GPU change counters, bumped on every command AND every
        # effective-cap application. The macro-stepped simulator snapshots a
        # GPU's counter when it plans a run of decode iterations at a fixed
        # cap; a counter mismatch afterwards means the plan must be cut short
        # at the next iteration boundary and re-derived from fresh caps.
        # ``version_total`` aggregates them so the per-event staleness check
        # is a single comparison.
        self.cap_version: List[int] = [0] * n_gpus
        self.version_total = 0

    # -- bookkeeping -----------------------------------------------------------
    def _sanity(self, where: str) -> None:
        """Sanitizer-mode self-check: every mutator leaves the worst-case
        draw within budget and every cap inside the spec envelope."""
        if self._worst_case() > self.budget + 1e-6:
            raise InvariantViolation(
                f"PowerManager.{where}: worst-case draw "
                f"{self._worst_case():.3f} W exceeds budget "
                f"{self.budget:.3f} W")
        if self._budget_target > self.budget + 1e-6:
            raise InvariantViolation(
                f"PowerManager.{where}: budget target "
                f"{self._budget_target:.3f} W above budget {self.budget:.3f} W")
        for g in range(self.n):
            for val in (self.commanded[g], self.effective[g]):
                if val < -1e-6 or val > self.max_cap + 1e-6:
                    raise InvariantViolation(
                        f"PowerManager.{where}: GPU {g} cap {val:.3f} W "
                        f"outside [0, {self.max_cap:.0f}] W")

    def _worst_case(self) -> float:
        """Budget-relevant power: for lowering commands still in flight the
        GPU may still draw its old (higher) cap."""
        return sum(max(c, e) for c, e in zip(self.commanded, self.effective))

    def _usable_budget(self) -> float:
        """Budget that cap *raises* may consume: during an in-flight budget
        shrink the (lower) target is authoritative, so the node cannot grab
        back watts it has already promised to the cluster."""
        return min(self.budget, self._budget_target)

    @property
    def budget_floor_w(self) -> float:
        return self.n * self.min_cap

    @property
    def budget_ceil_w(self) -> float:
        return self.n * self.max_cap

    @property
    def budget_op_inflight(self) -> bool:
        """A budget shrink has been issued but not yet committed."""
        return abs(self._budget_target - self.budget) > 1e-9

    def tick(self, now: float) -> None:
        """Apply pending cap changes that have become effective."""
        if not self.pending:           # hot path: called on every sim event
            return
        still = []
        for ch in self.pending:
            if ch.effective_at <= now:
                self.effective[ch.gpu] = ch.watts
                self.cap_version[ch.gpu] += 1
                self.version_total += 1
            else:
                still.append(ch)
        self.pending = still
        if self.sanitize:
            self._sanity("tick")

    def caps(self) -> List[float]:
        return list(self.effective)

    # -- commands --------------------------------------------------------------
    def set_cap(self, now: float, gpu: int, watts: float) -> float:
        """Command one cap. Returns when it takes effect. Raising a cap is
        refused (ValueError) if it would break the worst-case budget."""
        watts = min(max(watts, self.min_cap), self.max_cap)
        old = self.commanded[gpu]
        if watts > old:
            # clamp the raise to the worst-case budget headroom: concurrent
            # in-flight lowers still count at their old caps, so a raise can
            # never overshoot the node budget (source-before-sink invariant)
            mine = max(old, self.effective[gpu])
            headroom = self._usable_budget() - (self._worst_case() - mine)
            watts = max(min(watts, headroom), self.min_cap)
            if watts <= old + 1e-9:
                return now
            # raises take effect immediately (no draw above demand anyway)
            self.commanded[gpu] = watts
            self.effective[gpu] = watts
            self.cap_version[gpu] += 1
            self.version_total += 1
            self.history.append((now, gpu, watts))
            if self.sanitize:
                self._sanity("set_cap")
            return now
        ch = self.backend.set_cap(now, gpu, watts)
        self.commanded[gpu] = watts
        self.pending.append(ch)
        self.cap_version[gpu] += 1
        self.version_total += 1
        self.history.append((now, gpu, watts))
        if self.sanitize:
            self._sanity("set_cap")
        return ch.effective_at

    def shift(self, now: float, src: List[int], dst: List[int],
              watts_per_gpu: float) -> Tuple[float, float]:
        """Move watts from each src GPU to dst GPUs (source-before-sink).
        Lowers the sources now; returns (t_ready, freed_watts). The caller
        schedules ``apply_raise(t_ready, dst, freed_watts, dst_max)`` —
        the payload travels with the event so concurrent shifts and uniform
        redistributions cannot clobber each other."""
        total = 0.0
        t_ready = now
        for g in src:
            target = max(self.commanded[g] - watts_per_gpu, self.min_cap)
            moved = self.commanded[g] - target
            if moved <= 0:
                continue
            t_ready = max(t_ready, self.set_cap(now, g, target))
            total += moved
        return t_ready, total

    def apply_raise(self, now: float, dst: List[int], total: float,
                    dst_max: Optional[float] = None) -> None:
        """Second phase of ``shift``: distribute the freed watts to sinks."""
        if not dst or total <= 0:
            return
        self.tick(now)
        per = total / len(dst)
        cap = min(self.max_cap, dst_max) if dst_max else self.max_cap
        for g in dst:
            target = min(self.commanded[g] + per, cap)
            if target > self.commanded[g]:
                self.set_cap(now, g, target)

    def distribute_uniform(self, now: float,
                           gpus: Optional[List[int]] = None
                           ) -> Tuple[float, List[int], float]:
        """Paper Algorithm 1 line 14: DISTRIBUTEUNIFORMPOWER(AllGPUs).
        Lower-first then raise; returns (t_ready, gpus, per)."""
        gpus = list(range(self.n)) if gpus is None else gpus
        per = min(self._usable_budget() / self.n, self.max_cap)
        t_ready = now
        for g in gpus:
            if self.commanded[g] > per:
                t_ready = max(t_ready, self.set_cap(now, g, per))
        return t_ready, gpus, per

    def apply_uniform(self, now: float, gpus: List[int],
                      per: float) -> None:
        self.tick(now)
        for g in gpus:
            if self.commanded[g] < per:
                self.set_cap(now, g, per)

    # -- hierarchical budgets (cluster -> node) --------------------------------
    def shrink_budget(self, now: float,
                      delta_w: float) -> Tuple[float, float]:
        """First phase of a cluster-level budget move out of this node:
        lower GPU caps (highest first) until the commanded total fits the
        shrunk budget, but keep ``self.budget`` — the facility-accounting
        value — at its old level until ``commit_budget``. Returns
        ``(t_ready, freed_watts)``; the caller schedules the commit (and the
        sink node's ``grow_budget``) at ``t_ready``. Mirrors ``shift``'s
        source-before-sink discipline one level up."""
        assert not self.budget_op_inflight, \
            "budget operation already in flight"
        target = max(self.budget - delta_w, self.budget_floor_w)
        freed = self.budget - target
        if freed <= 1e-9:
            return now, 0.0
        self._budget_target = target
        t_ready = self._lower_caps_to(now, target)
        if self.sanitize:
            self._sanity("shrink_budget")
        return t_ready, freed

    def _lower_caps_to(self, now: float, target: float) -> float:
        """Cut GPU caps until the commanded total fits ``target``; returns
        when every lowered cap (including pre-existing in-flight lowers,
        which still count at their old caps in ``_worst_case()``) is in
        force — the release may not happen before they land, even if no
        *new* cap cuts are needed."""
        t_ready = max([now] + [ch.effective_at for ch in self.pending])
        excess = sum(self.commanded) - target
        if excess > 1e-9:
            # level-down water-fill: bring the highest caps to a common level
            # so the cut spreads evenly instead of gutting one GPU
            order = sorted(range(self.n), key=lambda i: -self.commanded[i])
            prefix, level, chosen_k = 0.0, self.min_cap, self.n
            for k in range(1, self.n + 1):
                prefix += self.commanded[order[k - 1]]
                nxt = self.commanded[order[k]] if k < self.n else -1e18
                level = (prefix - excess) / k
                if level >= nxt - 1e-12:
                    chosen_k = k
                    break
            level = max(level, self.min_cap)
            for g in order[:chosen_k]:
                if self.commanded[g] > level + 1e-9:
                    t_ready = max(t_ready, self.set_cap(now, g, level))
        return t_ready

    def emergency_shrink(self, now: float,
                         target_w: float) -> Tuple[float, float]:
        """Facility power emergency: force-throttle this node toward
        ``target_w`` watts, source-before-sink like ``shrink_budget`` —
        caps are cut first and the watts release only at the caller's
        ``commit_budget`` once the lowered caps are in force. Unlike
        ``shrink_budget`` this path is *preemptive*: it may land while a
        coordinator budget op is already in flight on this node, in which
        case the tighter of the two targets wins (the in-flight op's
        commit then lands at the emergency target — the sink still
        receives only the watts the op originally freed, so the facility
        sum can only fall). Targets clamp at the node's cap floor: a
        powered node cannot be throttled below spec minimums.

        Returns ``(t_ready, freed)`` where ``freed`` is relative to the
        currently-promised (usable) budget."""
        target = max(min(target_w, self.budget), self.budget_floor_w)
        freed = self._usable_budget() - target
        if freed <= 1e-9:
            return now, 0.0
        self._budget_target = target
        t_ready = self._lower_caps_to(now, target)
        if self.sanitize:
            self._sanity("emergency_shrink")
        return t_ready, freed

    def commit_budget(self, now: float) -> None:
        """Second phase: the lowered caps are in force; release the watts."""
        self.tick(now)
        self.budget = self._budget_target
        self.budget_history.append((now, self.budget))
        assert self._worst_case() <= self.budget + 1e-6, \
            (self._worst_case(), self.budget)
        if self.sanitize:
            self._sanity("commit_budget")

    def grow_budget(self, now: float, delta_w: float) -> float:
        """Raise this node's budget immediately (safe: more budget cannot
        violate anything) and water-fill the new headroom across GPU caps so
        the node can use it right away. Returns the watts actually absorbed
        (clamped by ``n * max_cap``); the caller returns any remainder to the
        source node so facility watts are conserved."""
        assert not self.budget_op_inflight, \
            "budget operation already in flight"
        new = min(self.budget + delta_w, self.budget_ceil_w)
        absorbed = new - self.budget
        if absorbed <= 1e-9:
            return 0.0
        self.budget = new
        self._budget_target = new
        self.budget_history.append((now, self.budget))
        left = absorbed
        # least-headroom first: a GPU that clamps at max_cap rolls its
        # surplus share to the ones that still have room
        order = sorted(range(self.n),
                       key=lambda i: self.max_cap - self.commanded[i])
        for idx, g in enumerate(order):
            share = left / (self.n - idx)
            give = min(share, self.max_cap - self.commanded[g])
            if give > 1e-9:
                self.set_cap(now, g, self.commanded[g] + give)
                left -= give
        if self.sanitize:
            self._sanity("grow_budget")
        return absorbed

    # -- fleet membership (node power on/off) ----------------------------------
    @property
    def powered(self) -> bool:
        return self.budget > 0.0

    def power_off(self, now: float) -> float:
        """Take the whole node off the facility budget (leave/failure). A
        powered-off node draws nothing and holds no watts, so its budget and
        all caps drop to zero immediately — there is no enforcement latency
        to wait out because the node is not *lowering under load*, it is
        gone. Returns the watts released to the facility."""
        released = self.budget
        self.budget = 0.0
        self._budget_target = 0.0
        self.pending.clear()
        for g in range(self.n):
            self.commanded[g] = 0.0
            self.effective[g] = 0.0
            self.cap_version[g] += 1
        self.version_total += self.n
        self.budget_history.append((now, 0.0))
        if self.sanitize:
            self._sanity("power_off")
        return released

    def power_on(self, now: float, budget_w: float) -> float:
        """Bring the node onto the facility budget with ``budget_w`` watts
        (clamped to [floor, ceiling]) and uniform per-GPU caps. Caps take
        effect immediately: a node powering on cannot be drawing above its
        fresh caps. Returns the watts actually absorbed — the caller keeps
        any remainder for other nodes (facility conservation)."""
        assert not self.powered, "power_on on a live node"
        budget = min(max(budget_w, self.budget_floor_w), self.budget_ceil_w)
        if budget > budget_w + 1e-9:
            raise ValueError(
                f"power_on granted {budget_w} W < floor {self.budget_floor_w} W")
        self.budget = budget
        self._budget_target = budget
        per = min(budget / self.n, self.max_cap)
        for g in range(self.n):
            self.commanded[g] = per
            self.effective[g] = per
            self.cap_version[g] += 1
        self.version_total += self.n
        self.budget_history.append((now, budget))
        self.history.append((now, -1, per))     # -1: whole-node uniform set
        if self.sanitize:
            self._sanity("power_on")
        return budget

    def at_limits(self, src: List[int], dst: List[int],
                  dst_max: Optional[float] = None) -> bool:
        """POWERLIMITSREACHED: no more watts can move src -> dst."""
        dst_cap = min(self.max_cap, dst_max) if dst_max else self.max_cap
        src_done = all(self.commanded[g] <= self.min_cap + 1e-6 for g in src)
        dst_done = all(self.commanded[g] >= dst_cap - 1e-6 for g in dst)
        return src_done or dst_done or not src or not dst
