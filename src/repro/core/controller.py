"""RAPID resource controllers: Algorithm 1 (reactive dynamic scheduling) and
the static / partially-dynamic policies evaluated in the paper (Section 5).

The controller is *observation-driven*: it sees recent TTFT/TPOT, queue
depths, and the power manager — no latency prediction or offline profiling
(paper Section 3.3, contrast with WindServe). Decisions:

  MovePower(decode -> prefill)   when TTFT stressed and TPOT healthy
  MoveGPU(decode -> prefill)     when power limits reached
  (and the symmetric direction)

with a cooldown between actions (implicit hysteresis), queue depth as the
early-warning trigger, and a decode power ceiling of 600 W (the paper's
observation that decode does not scale beyond it, Fig 9a).
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.core.power_manager import PowerManager


@dataclasses.dataclass
class ControllerConfig:
    ttft_slo: float = 1.0
    tpot_slo: float = 0.040
    queue_threshold: int = 4        # THRESHOLD on |Q_P|
    cooldown_s: float = 3.0         # COOLDOWN for GPU moves (paper: 2-6 s)
    power_cooldown_s: float = 0.5   # power loop runs at sub-second pace
    min_time_s: float = 0.25        # MIN_TIME control period
    power_step_w: float = 50.0
    min_prefill_gpus: int = 1       # MIN_P
    min_decode_gpus: int = 1
    decode_cap_max_w: float = 600.0  # decode doesn't scale beyond (Fig 9)
    gpu_move_drain_s: float = 3.0   # role flip drain cost (paper: 2-5 s)
    allow_power: bool = True        # DynPower
    allow_gpu: bool = False         # DynGPU


@dataclasses.dataclass
class Observation:
    now: float
    ttft_p90: float                 # recent window
    tpot_p90: float
    q_prefill: int
    q_decode: int


@dataclasses.dataclass
class Decision:
    kind: str                       # "none" | "power" | "gpu"
    direction: str = ""             # "d2p" | "p2d"
    note: str = ""


@dataclasses.dataclass
class NodeStress:
    """SLO-relative stress of one node, as seen by the cluster coordinator.

    ``stress`` > 1 means the node is violating (or about to violate) an SLO;
    well below 1 means it has power to spare. The coordinator moves node
    budget from the least- to the most-stressed node (``core.cluster``)."""
    node_id: int
    now: float
    ttft_p90: float
    tpot_p90: float
    q_prefill: int
    q_decode: int
    ttft_stress: float              # ttft_p90 / ttft_slo
    tpot_stress: float              # tpot_p90 / tpot_slo

    @property
    def stress(self) -> float:
        return max(self.ttft_stress, self.tpot_stress)

    @property
    def hot_role(self) -> str:
        """Role the node is starved for: TTFT stress means prefill capacity
        is short, TPOT stress means decode capacity is short. Drives the
        direction of a cluster-level MoveGPU."""
        return "prefill" if self.ttft_stress >= self.tpot_stress else "decode"


def stress_from(obs: Observation, ttft_slo: float, tpot_slo: float,
                node_id: int = 0) -> NodeStress:
    return NodeStress(
        node_id=node_id, now=obs.now,
        ttft_p90=obs.ttft_p90, tpot_p90=obs.tpot_p90,
        q_prefill=obs.q_prefill, q_decode=obs.q_decode,
        ttft_stress=obs.ttft_p90 / max(ttft_slo, 1e-9),
        tpot_stress=obs.tpot_p90 / max(tpot_slo, 1e-9),
    )


class RapidController:
    """Algorithm 1. Interacts with a cluster through a narrow interface:
    the PowerManager plus role lists (indices of prefill/decode GPUs)."""

    def __init__(self, cfg: ControllerConfig, pm: PowerManager):
        self.cfg = cfg
        self.pm = pm
        self.last_move_time = -1e9      # any move (gates the power loop)
        self.last_gpu_time = -1e9       # GPU moves (long cooldown)
        self.trace: List[tuple] = []    # (t, kind, direction)

    # role lists are owned by the cluster; controller reads them each tick
    def tick(self, obs: Observation, prefill_gpus: List[int],
             decode_gpus: List[int]) -> Decision:
        c = self.cfg
        now = obs.now
        if now - self.last_move_time < c.power_cooldown_s:
            return Decision("none", note="cooldown")

        ttft_bad = obs.ttft_p90 > c.ttft_slo
        tpot_bad = obs.tpot_p90 > c.tpot_slo
        queue_hot = obs.q_prefill > c.queue_threshold

        # --- prefill-side stress: TTFT over SLO, queue building, decode OK --
        if ttft_bad and queue_hot and not tpot_bad:
            return self._relieve(now, "d2p", src=decode_gpus, dst=prefill_gpus,
                                 src_min=c.min_decode_gpus,
                                 dst_max_w=self.pm.max_cap)
        # --- decode-side stress: TPOT over SLO, prefill healthy --------------
        if tpot_bad and not ttft_bad:
            return self._relieve(now, "p2d", src=prefill_gpus, dst=decode_gpus,
                                 src_min=c.min_prefill_gpus,
                                 dst_max_w=c.decode_cap_max_w)
        return Decision("none")

    def _relieve(self, now: float, direction: str, src: List[int],
                 dst: List[int], src_min: int, dst_max_w: float) -> Decision:
        c = self.cfg
        if c.allow_power and not self.pm.at_limits(src, dst, dst_max_w):
            self.last_move_time = now
            self.trace.append((now, "power", direction))
            return Decision("power", direction)
        if c.allow_gpu and len(src) > src_min and \
                now - self.last_gpu_time >= c.cooldown_s:
            self.last_move_time = now
            self.last_gpu_time = now
            self.trace.append((now, "gpu", direction))
            return Decision("gpu", direction,
                            note="power limits reached" if c.allow_power else "")
        if c.allow_power and not c.allow_gpu:
            # power-only policy saturated: nothing to do
            return Decision("none", note="power saturated")
        return Decision("none", note="at limits")


# ---------------------------------------------------------------------------
# policy presets (paper Section 5 configurations)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StaticPolicy:
    """User-fixed GPU split + per-role caps, e.g. 4P-750W/4D-450W."""
    n_prefill: int
    n_decode: int
    prefill_w: float
    decode_w: float
    name: str = ""

    def label(self) -> str:
        if self.name:
            return self.name
        if abs(self.prefill_w - self.decode_w) < 1e-9:
            return f"{self.n_prefill}P{self.n_decode}D-{self.prefill_w:.0f}W"
        return (f"{self.n_prefill}P-{self.prefill_w:.0f}W/"
                f"{self.n_decode}D-{self.decode_w:.0f}W")

    def caps(self) -> List[float]:
        return ([self.prefill_w] * self.n_prefill +
                [self.decode_w] * self.n_decode)


def policy_4p4d(w: float = 600.0) -> StaticPolicy:
    return StaticPolicy(4, 4, w, w)


def policy_5p3d(w: float = 600.0) -> StaticPolicy:
    return StaticPolicy(5, 3, w, w)


def policy_nonuniform(pw: float = 750.0, dw: float = 450.0) -> StaticPolicy:
    return StaticPolicy(4, 4, pw, dw)
