"""Power-performance model, calibrated to RAPID Figure 4 (MI300X) with a
TPU-v5e parameter set for the target hardware.

Paper observations (Fig 4a/b, Section 3.3):
  * prefill (compute-bound): up to 1.8x speedup for 1.87x power
    (400 W -> 750 W), still improving until ~700 W, then flattens;
  * decode (memory-bound): 1.3-1.5x, flattening beyond ~600 W.

We model speedup-vs-power with a saturating exponential
    s(p) = 1 + a * (1 - exp(-(p - p_min) / tau))
and fit (a, tau) so s(750) and the flattening points match the figure.

The same asymmetry holds on TPU: MXU throughput scales ~linearly with
frequency (DVFS), HBM bandwidth barely moves — so prefill tracks the power
knob and decode saturates early. The TPU parameter set expresses that; the
MI300X set is used for the paper-reproduction experiments.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class PowerCurve:
    a: float          # asymptotic speedup - 1
    tau: float        # watts scale
    p_min: float      # minimum cap (reference point, speedup = 1)
    p_max: float      # TBP

    def speedup(self, p: float) -> float:
        p = min(max(p, self.p_min), self.p_max)
        return 1.0 + self.a * (1.0 - math.exp(-(p - self.p_min) / self.tau))

    def rel(self, p: float) -> float:
        """Throughput multiplier relative to max power (<= 1)."""
        return self.speedup(p) / self.speedup(self.p_max)


@dataclasses.dataclass(frozen=True)
class PowerModel:
    name: str
    prefill: PowerCurve
    decode: PowerCurve
    idle_w: float                 # idle draw
    enforce_latency_s: float      # cap-change enforcement (Fig 4c: O(100ms))

    def speedup(self, role: str, p: float) -> float:
        return (self.prefill if role == "prefill" else self.decode).speedup(p)

    def rel(self, role: str, p: float) -> float:
        return (self.prefill if role == "prefill" else self.decode).rel(p)

    def demand(self, role: str, busy: bool) -> float:
        """Unconstrained power demand of a GPU in the given state."""
        if not busy:
            return self.idle_w
        curve = self.prefill if role == "prefill" else self.decode
        return curve.p_max if role == "prefill" else 0.85 * curve.p_max

    def draw(self, role: str, cap: float, busy: bool) -> float:
        return min(cap, self.demand(role, busy))

    def joules(self, role: str, cap: float, dt_s: float,
               busy: bool = True) -> float:
        """Energy drawn over ``dt_s`` seconds at the given cap and state —
        the per-request energy accounting integrates this along each
        request's prefill/decode path (``core.simulator``)."""
        return self.draw(role, cap, busy) * dt_s


def mi300x() -> PowerModel:
    """Calibration: prefill s(750)=1.80 with tau=200 (still rising at 700);
    decode s(750)=1.40 with tau=90 (>=90% of gain by 600 W)."""
    return PowerModel(
        name="mi300x",
        prefill=PowerCurve(a=0.968, tau=200.0, p_min=400.0, p_max=750.0),
        decode=PowerCurve(a=0.408, tau=90.0, p_min=400.0, p_max=750.0),
        idle_w=90.0,
        enforce_latency_s=0.3,
    )


def h100() -> PowerModel:
    """H100 SXM (300-700 W cap range). Same compute/memory asymmetry as
    MI300X: SM clocks track the power knob almost to TBP (prefill ~1.8x for
    300->700 W), HBM3 bandwidth saturates early (decode ~1.4x, >=90% of the
    gain by ~550 W). Used for the heterogeneous multi-vendor cluster
    experiments (fig10)."""
    return PowerModel(
        name="h100",
        prefill=PowerCurve(a=0.95, tau=190.0, p_min=300.0, p_max=700.0),
        decode=PowerCurve(a=0.38, tau=85.0, p_min=300.0, p_max=700.0),
        idle_w=70.0,
        enforce_latency_s=0.3,
    )


def tpu_v5e_group() -> PowerModel:
    """TPU adaptation: an 8-chip v5e group treated as the 'node'. Per-chip
    envelope ~200 W scaled; prefill ~ linear in clock (compute term), decode
    saturates once HBM-bound. Used for target-hardware projections."""
    return PowerModel(
        name="tpu_v5e_group",
        prefill=PowerCurve(a=0.90, tau=55.0, p_min=110.0, p_max=200.0),
        decode=PowerCurve(a=0.30, tau=25.0, p_min=110.0, p_max=200.0),
        idle_w=35.0,
        enforce_latency_s=0.3,
    )


def get_power_model(name: str) -> PowerModel:
    return {"mi300x": mi300x, "h100": h100, "tpu_v5e": tpu_v5e_group}[name]()
