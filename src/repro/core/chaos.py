"""Seeded, deterministic chaos harness for the elastic fleet.

Real power-capped clusters are not perturbed politely: grid demand-response
slashes the facility cap mid-burst, a rack PDU takes k nodes down in one
instant, a transfer link drops or wedges mid-KV-migration, and traffic
surges land exactly when capacity is scarcest. ``ChaosEngine`` injects
these as *scenarios* — coordinated schedules on the shared ``EventLoop`` —
not ad-hoc toggles, so an entire chaos run is a pure function of its seed:
two runs with the same seed and schedule produce bit-identical per-request
records (the fig13 gate), and every fault replays exactly under
``RAPID_SANITIZE=1``.

Fault classes and which layer absorbs each:

* **Facility power emergency** (``schedule_power_emergency``) — the
  facility's effective limit drops to a fraction of nameplate for a
  window. Absorbed by ``FleetManager``/``PowerManager.emergency_shrink``:
  source-before-sink force-throttle, joins clamp against the slashed
  limit, autoscaler holds, coordinator freezes its power plan; the freed
  headroom re-levels back on restore.
* **Correlated rack failure** (``schedule_rack_failure``) — k co-located
  nodes die in one instant. Absorbed by ``FleetManager._on_fail_group``:
  per-node eviction/requeue, ONE facility re-level with the pooled watts.
* **Migration link fault** (``schedule_link_fault``) — the source node's
  outbound link drops (``mode="fail"``) or wedges (``mode="stall"``) for
  a window. Absorbed by the migration engine's retry/timeout/backoff: a
  failed transfer retries with capped exponential backoff against the
  per-request deadline, then degrades to requeue-with-KV-loss; a stalled
  transfer (and the pipelined burst behind it) simply waits the stall out.
* **Load surge** (``schedule_surge``) — a seeded burst of extra arrivals.
  Absorbed by SLO-aware admission control (``PowerAwareRouter.decide``):
  overload sheds the lowest-value requests instead of queueing everyone
  into violation.

Determinism contract: randomness is drawn ONLY at schedule time (surge
inter-arrival gaps, ``inject``'s scenario layout), from a
``np.random.default_rng(seed)`` owned by this engine (simcheck RC002). The
runtime fault hook ``_link_fault`` is a pure function of its arguments and
the pre-built window list. simcheck RC006 enforces that this module is the
only place in ``core/`` that installs fault hooks.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.fleet import FleetManager
from repro.core.goodput import RequestRecord
from repro.core.simulator import SimRequest


@dataclasses.dataclass
class ChaosConfig:
    """Knobs for ``ChaosEngine``: the seed owns ALL schedule-time
    randomness (the run itself is deterministic)."""
    seed: int = 0
    # a dropped transfer is detected this far into its (attempted)
    # transfer time — the wasted link occupancy before the retry path runs
    fail_detect_frac: float = 0.5


class ChaosEngine:
    """Fault scheduler bound to one ``FleetManager`` (and through it the
    cluster and shared loop). Construct it, script a scenario with the
    ``schedule_*`` calls (or ``inject`` for a seeded random one), then run
    the cluster normally."""

    def __init__(self, fleet: FleetManager,
                 cfg: Optional[ChaosConfig] = None):
        self.fm = fleet
        self.cs = fleet.cs
        self.loop = fleet.loop
        self.cfg = cfg or ChaosConfig()
        self.rng = np.random.default_rng(self.cfg.seed)
        self.trace: List[tuple] = []     # (t_scheduled, kind, detail)
        # per-node link fault windows: node_id -> [(t0, t1, mode)]
        self._link_down: dict = {}
        # telemetry degradation windows: (t0, t1, mode, node_ids|None)
        # where mode is "freeze" | "drop" | ("sample", period_s)
        self._tel_windows: List[tuple] = []
        # the sanctioned injection points (simcheck RC006)
        fleet.link_fault_fn = self._link_fault
        fleet.cs.telemetry.telemetry_fault_fn = self._telemetry_fault

    # ---------------- scenario scheduling ----------------
    def schedule_power_emergency(self, t: float, frac: float,
                                 duration_s: Optional[float] = None) -> None:
        """Facility cap slashed to ``frac`` of nameplate at ``t`` for
        ``duration_s`` (indefinite if ``None``)."""
        self.trace.append((t, "power_emergency", (frac, duration_s)))
        self.fm.schedule_emergency(t, frac, duration_s)

    def schedule_rack_failure(self, t: float,
                              node_ids: Sequence[int]) -> None:
        """Rack-scope correlated failure: every listed node dies at ``t``
        in one instant (one facility re-level, not k)."""
        self.trace.append((t, "rack_failure", tuple(node_ids)))
        self.fm.schedule_fail_group(t, node_ids)

    def schedule_link_fault(self, t: float, node_id: int,
                            duration_s: float, mode: str = "fail") -> None:
        """Outbound KV-transfer link on ``node_id`` is faulty over
        ``[t, t + duration_s)``: ``"fail"`` drops transfers (retry path),
        ``"stall"`` wedges them (they wait the window out)."""
        assert mode in ("fail", "stall"), mode
        self.trace.append((t, "link_fault", (node_id, duration_s, mode)))
        self._link_down.setdefault(node_id, []).append(
            (t, t + duration_s, mode))
        self._link_down[node_id].sort()

    def schedule_telemetry_freeze(self, t: float, duration_s: float,
                                  node_ids: Optional[Sequence[int]] = None
                                  ) -> None:
        """Telemetry pipeline wedges over ``[t, t + duration_s)``: every
        controller read of the listed nodes (all nodes if ``None``) serves
        the last-known-good snapshot, and staleness grows for the window.
        Heartbeats still flow — this is the collector, not the network."""
        self.trace.append((t, "telemetry_freeze", (duration_s, node_ids)))
        self._tel_windows.append(
            (t, t + duration_s, "freeze",
             frozenset(node_ids) if node_ids is not None else None))
        self._tel_windows.sort(key=lambda w: (w[0], w[1]))

    def schedule_telemetry_dropout(self, t: float, duration_s: float,
                                   node_ids: Optional[Sequence[int]] = None
                                   ) -> None:
        """Telemetry path partitions over ``[t, t + duration_s)``: state
        reads freeze AND the listed nodes' heartbeats are swallowed — the
        failure detector may falsely suspect healthy nodes (and, past its
        dead timeout, fence them)."""
        self.trace.append((t, "telemetry_dropout", (duration_s, node_ids)))
        self._tel_windows.append(
            (t, t + duration_s, "drop",
             frozenset(node_ids) if node_ids is not None else None))
        self._tel_windows.sort(key=lambda w: (w[0], w[1]))

    def schedule_telemetry_period(self, t: float, duration_s: float,
                                  period_s: float,
                                  node_ids: Optional[Sequence[int]] = None
                                  ) -> None:
        """Coarse sample-and-hold telemetry over ``[t, t + duration_s)``:
        reads refresh at most once per ``period_s``, bounding staleness by
        the period (an honest but slow pipeline)."""
        self.trace.append((t, "telemetry_period", (duration_s, period_s)))
        self._tel_windows.append(
            (t, t + duration_s, ("sample", period_s),
             frozenset(node_ids) if node_ids is not None else None))
        self._tel_windows.sort(key=lambda w: (w[0], w[1]))

    def schedule_controller_crash(self, t: float,
                                  duration_s: float) -> None:
        """Coordinator + autoscaler crash for ``duration_s``: headless
        fail-safe mode, epoch-fenced grants, snapshot+replay recovery
        (see ``FleetManager.schedule_controller_crash``)."""
        self.trace.append((t, "controller_crash", duration_s))
        self.fm.schedule_controller_crash(t, duration_s)

    def schedule_node_death(self, t: float, node_id: int) -> None:
        """Physical node death WITHOUT oracle detection: recovery is gated
        on the heartbeat detector noticing (``FleetManager.schedule_die``).
        Requires a ``HeartbeatDetector`` attached to the fleet — without
        one the stranded work never requeues."""
        assert self.fm.detector is not None, \
            "schedule_node_death needs a HeartbeatDetector on the fleet " \
            "(use schedule_rack_failure for oracle-detected deaths)"
        self.trace.append((t, "node_death", node_id))
        self.fm.schedule_die(t, node_id)

    def schedule_surge(self, t: float, n: int, qps: float,
                       input_tokens: int = 512, output_tokens: int = 128,
                       ttft_slo: float = 1.0,
                       tpot_slo: float = 0.040) -> None:
        """Seeded traffic burst: ``n`` extra requests from ``t`` at
        ``qps`` (exponential inter-arrival gaps drawn NOW, at schedule
        time — the run itself stays deterministic). Call before
        ``cluster.run``: the records pre-seed the cluster's ledger so run
        termination accounts for them."""
        self.trace.append((t, "surge", (n, qps)))
        gaps = self.rng.exponential(1.0 / qps, size=n)
        at = t + np.cumsum(gaps)
        rid = len(self.cs.records)
        for i in range(n):
            rec = RequestRecord(rid + i, float(at[i]), input_tokens,
                                output_tokens, ttft_slo=ttft_slo,
                                tpot_slo=tpot_slo)
            self.cs.records.append(rec)
            self.loop.push(max(float(at[i]), self.loop.now),
                           self.cs._handle, "arrival",
                           (SimRequest(rec), None))

    def inject(self, horizon_s: float, n_emergencies: int = 1,
               emergency_frac: Tuple[float, float] = (0.5, 0.75),
               emergency_dur_frac: float = 0.2,
               n_rack_failures: int = 1, rack_size: int = 2,
               rejoin_after_s: Optional[float] = None,
               n_link_faults: int = 2,
               link_fault_s: float = 0.5) -> None:
        """Seeded random scenario over ``[0, horizon_s)``: emergencies,
        correlated failures (with optional rejoins), and link faults laid
        out by this engine's rng — the randomized-schedule half of the
        chaos property tests. Deterministic per seed."""
        for _ in range(n_emergencies):
            t0 = float(self.rng.uniform(0.1, 0.7) * horizon_s)
            frac = float(self.rng.uniform(*emergency_frac))
            self.schedule_power_emergency(
                t0, frac, emergency_dur_frac * horizon_s)
        n_nodes = len(self.cs.nodes)
        for _ in range(n_rack_failures):
            t0 = float(self.rng.uniform(0.1, 0.8) * horizon_s)
            k = min(rack_size, max(n_nodes - 1, 1))
            start = int(self.rng.integers(0, max(n_nodes - k, 0) + 1))
            rack = list(range(start, start + k))
            self.schedule_rack_failure(t0, rack)
            if rejoin_after_s is not None:
                for nid in rack:
                    self.fm.schedule_join(t0 + rejoin_after_s, nid)
        for _ in range(n_link_faults):
            t0 = float(self.rng.uniform(0.1, 0.9) * horizon_s)
            nid = int(self.rng.integers(0, n_nodes))
            mode = "fail" if self.rng.random() < 0.5 else "stall"
            self.schedule_link_fault(t0, nid, link_fault_s, mode)

    # ---------------- runtime fault hooks ----------------
    def _telemetry_fault(self, node_id: int, now: float):
        """Deterministic telemetry verdict for one (node, now) read:
        ``None`` (clean), ``"freeze"``, ``"drop"`` or
        ``("sample", period_s)``. Pure function of the pre-built window
        list; overlapping windows: the harshest mode wins (drop > freeze
        > sampled)."""
        verdict = None
        for (t0, t1, mode, nids) in self._tel_windows:
            if not (t0 <= now < t1):
                continue
            if nids is not None and node_id not in nids:
                continue
            if mode == "drop":
                return "drop"
            if mode == "freeze":
                verdict = "freeze"
            elif verdict is None:
                verdict = mode
        return verdict

    def _link_fault(self, src_id: int, t_start: float,
                    dt: float) -> Optional[Tuple[str, float]]:
        """Deterministic link verdict for a transfer occupying
        ``[t_start, t_start + dt)`` on ``src_id``'s outbound link:
        ``None`` (clean), ``("stall", t_resume)`` or
        ``("fail", t_detect)``. Pure function of the window list."""
        for (t0, t1, mode) in self._link_down.get(src_id, ()):
            if t_start < t1 and t_start + dt > t0:
                if mode == "stall":
                    return ("stall", t1)
                return ("fail",
                        max(t0, t_start) + self.cfg.fail_detect_frac * dt)
        return None
