"""Multi-node cluster simulation: N power-capped nodes under one facility
budget, a power-aware router, and a cluster coordinator that moves *node
budgets* the same way ``PowerManager.shift`` moves per-GPU watts.

Two-level power hierarchy (paper Algorithm 1, composed):

  facility budget
    -> node budgets     (ClusterCoordinator, source-before-sink: the source
                         node lowers its GPU caps first via ``shrink_budget``;
                         only when they are in force does ``commit_budget``
                         release the watts and the sink ``grow_budget`` them)
    -> per-GPU caps     (per-node PowerManager + RapidController, unchanged)

Invariant asserted every coordinator tick AND after every budget handoff:
``sum(node budgets) <= facility budget`` with worst-case accounting — a node
whose budget shrink is still in flight counts at its OLD budget, exactly as
an in-flight GPU cap lower counts at its old cap.

All nodes advance on one shared ``EventLoop``; arrivals enter through the
router (least-power-adjusted-load with a prefill-queue-age early warning,
mirroring ``NodeSimulator._queue_ttft_estimate``) or pinned per node for
heterogeneous / skewed workload experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.controller import (ControllerConfig, NodeStress, StaticPolicy)
from repro.core.costmodel import MI300X, GPUSpec
from repro.core.events import EventLoop
from repro.core.goodput import GoodputSummary, RequestRecord, summarize
from repro.core.power_model import PowerModel
from repro.core.simulator import NodeSimulator, SimRequest, Workload


@dataclasses.dataclass
class ClusterConfig:
    """Coordinator knobs (cluster-level analogue of ControllerConfig)."""
    period_s: float = 1.0           # coordinator tick
    shift_step_w: float = 200.0     # watts per node-budget move
    cooldown_s: float = 2.0         # between budget moves
    stress_gap: float = 0.25        # min (dst.stress - src.stress) to act
    dst_stress_min: float = 1.0     # sink must be (about to be) violating
    src_stress_max: float = 0.9     # source must be comfortably inside SLO
    allow_shift: bool = True        # False: static node budgets (baseline)


class PowerAwareRouter:
    """Dispatch to the node with the least power-adjusted load. Ties (e.g.
    an idle cluster) round-robin via a rotating start index so request 0..k
    don't all pile onto node 0."""

    def __init__(self):
        self._rr = 0
        self.trace: List[tuple] = []    # (t, node_id)

    def pick(self, now: float, nodes: Sequence[NodeSimulator]) -> NodeSimulator:
        k = self._rr % len(nodes)
        self._rr += 1
        order = list(nodes[k:]) + list(nodes[:k])
        node = min(order, key=lambda nd: nd.router_load())
        self.trace.append((now, node.node_id))
        return node


class ClusterSimulator:
    """N ``NodeSimulator`` nodes on one clock under a facility power budget."""

    def __init__(self, cfg: ModelConfig, policy: StaticPolicy, n_nodes: int,
                 node_budget_w: float = 4800.0,
                 facility_budget_w: Optional[float] = None,
                 ctrl_cfg: Optional[ControllerConfig] = None,
                 cluster_cfg: Optional[ClusterConfig] = None,
                 gpu: GPUSpec = MI300X, power: Optional[PowerModel] = None,
                 coalesced: bool = False, seed: int = 0,
                 policies: Optional[Sequence[StaticPolicy]] = None,
                 node_budgets: Optional[Sequence[float]] = None):
        self.loop = EventLoop()
        budgets = list(node_budgets) if node_budgets else \
            [node_budget_w] * n_nodes
        assert len(budgets) == n_nodes
        self.facility_budget_w = facility_budget_w or float(sum(budgets))
        assert sum(budgets) <= self.facility_budget_w + 1e-6
        pols = list(policies) if policies else [policy] * n_nodes
        self.nodes = [
            NodeSimulator(cfg, pols[i], node_budget_w=budgets[i], gpu=gpu,
                          power=power, ctrl_cfg=ctrl_cfg, coalesced=coalesced,
                          seed=seed + i, loop=self.loop, node_id=i)
            for i in range(n_nodes)
        ]
        self.router = PowerAwareRouter()
        self.ccfg = cluster_cfg or ClusterConfig()
        self.records: List[RequestRecord] = []
        self.shift_trace: List[tuple] = []    # (t, src, dst, watts)
        self.budget_trace: List[tuple] = []   # (t, [budgets], total)
        self._inflight: set = set()           # node ids with a budget op
        self._last_shift_t = -1e9

    # ---------------- invariants ----------------
    def assert_facility_invariant(self):
        """Worst-case facility accounting: in-flight budget shrinks count at
        the old (higher) budget, so this must hold at every instant."""
        total = sum(nd.pm.budget for nd in self.nodes)
        assert total <= self.facility_budget_w + 1e-6, \
            (total, self.facility_budget_w)
        for nd in self.nodes:
            assert nd.pm._worst_case() <= nd.pm.budget + 1e-6, \
                (nd.node_id, nd.pm._worst_case(), nd.pm.budget)
        return total

    # ---------------- event handling ----------------
    def _handle(self, kind: str, payload=None):
        now = self.loop.now
        if kind == "arrival":
            req, node_id = payload
            node = (self.nodes[node_id] if node_id is not None
                    else self.router.pick(now, self.nodes))
            node.handle("arrival", req)
        elif kind == "cluster_ctrl":
            self._on_cluster_ctrl()
        elif kind == "budget_ready":
            self._on_budget_ready(*payload)
        else:
            raise ValueError(f"unknown cluster event {kind!r}")

    def _on_budget_ready(self, src_id: int, dst_id: int, freed: float):
        now = self.loop.now
        src, dst = self.nodes[src_id], self.nodes[dst_id]
        src.pm.commit_budget(now)
        absorbed = dst.pm.grow_budget(now, freed)
        if absorbed < freed - 1e-9:
            # sink at its ceiling: return the remainder to the source so
            # facility watts are conserved
            src.pm.grow_budget(now, freed - absorbed)
        self._inflight.discard(src_id)
        self._inflight.discard(dst_id)
        self.shift_trace.append((now, src_id, dst_id, absorbed))
        self.assert_facility_invariant()

    def _on_cluster_ctrl(self):
        now = self.loop.now
        total = self.assert_facility_invariant()
        self.budget_trace.append(
            (now, [nd.pm.budget for nd in self.nodes], total))
        c = self.ccfg
        if (c.allow_shift and not self._inflight
                and now - self._last_shift_t >= c.cooldown_s):
            stresses = [nd.stress_summary() for nd in self.nodes]
            dst = max(stresses, key=lambda s: s.stress)
            src = min(stresses, key=lambda s: s.stress)
            if (dst.node_id != src.node_id
                    and dst.stress >= c.dst_stress_min
                    and src.stress <= c.src_stress_max
                    and dst.stress - src.stress >= c.stress_gap):
                src_nd = self.nodes[src.node_id]
                if src_nd.pm.budget - c.shift_step_w >= \
                        src_nd.pm.budget_floor_w - 1e-9:
                    t_ready, freed = src_nd.pm.shrink_budget(
                        now, c.shift_step_w)
                    if freed > 0:
                        self._inflight.update((src.node_id, dst.node_id))
                        self._last_shift_t = now
                        self.loop.push(t_ready, self._handle, "budget_ready",
                                       (src.node_id, dst.node_id, freed))
        if self.loop.heap:
            self.loop.push(now + c.period_s, self._handle, "cluster_ctrl")

    # ---------------- driving ----------------
    def _seed_arrivals(self, workload: Optional[Workload],
                       pinned: Optional[Dict[int, Workload]]):
        rid = 0
        streams = []
        if workload is not None:
            streams.append((None, workload))
        for node_id, wl in (pinned or {}).items():
            streams.append((node_id, wl))
        assert streams, "no workload given"
        for node_id, wl in streams:
            for (t, it, ot, ts, ps) in wl.entries:
                rec = RequestRecord(rid, t, it, ot, ttft_slo=ts, tpot_slo=ps)
                rid += 1
                self.records.append(rec)
                self.loop.push(t, self._handle, "arrival",
                               (SimRequest(rec), node_id))

    def n_unfinished(self) -> int:
        # every record lands in exactly one node via submit(); counters keep
        # the per-event termination check O(1)
        return len(self.records) - sum(nd.finished_count for nd in self.nodes)

    def run(self, workload: Optional[Workload] = None,
            pinned: Optional[Dict[int, Workload]] = None,
            horizon_s: float = 1e5) -> GoodputSummary:
        """``workload``: arrivals dispatched by the router. ``pinned``:
        {node_id: Workload} delivered to that node directly (skewed /
        heterogeneous per-node experiments). Both may be combined."""
        self._seed_arrivals(workload, pinned)
        for nd in self.nodes:
            nd.start()
        self.loop.push(0.0, self._handle, "cluster_ctrl")
        self.loop.run(lambda: self.n_unfinished() == 0, horizon_s)
        return self.summary()

    def summary(self) -> GoodputSummary:
        duration = max((r.finish or self.loop.now) for r in self.records) \
            if self.records else self.loop.now
        per_node_w = []
        for nd in self.nodes:
            if nd.power_samples:
                per_node_w.append(float(np.mean(
                    [w for _, w in nd.power_samples])))
            else:
                per_node_w.append(sum(nd.pm.effective))
        return summarize(self.records, duration, float(sum(per_node_w)))

    def node_summaries(self) -> List[GoodputSummary]:
        return [nd.summary() for nd in self.nodes]

    def node_stresses(self) -> List[NodeStress]:
        return [nd.stress_summary() for nd in self.nodes]
