"""Multi-node cluster simulation: N power-capped (possibly heterogeneous)
nodes under one facility budget, a power-aware router, and a cluster
coordinator that jointly manages node *budgets* (MovePower one level up)
and node *roles* (MoveGPU one level up).

Two-level control hierarchy (paper Algorithm 1, composed):

  facility budget
    -> node budgets     (ClusterCoordinator, source-before-sink: the source
                         node lowers its GPU caps first via ``shrink_budget``;
                         only when they are in force does ``commit_budget``
                         release the watts and the sink ``grow_budget`` them)
    -> per-GPU caps     (per-node PowerManager + RapidController, unchanged)
  cluster role mix      (ClusterCoordinator: when a stressed node cannot be
                         relieved by watts alone — its budget at the
                         facility-fair ceiling, or the source pool exhausted —
                         flip one GPU toward the starved role on the
                         least-stressed node that can afford it, with the
                         same drain discipline the node controller uses)

Invariant asserted every coordinator tick, after every budget handoff, AND
at both ends of every role flip (a drain in flight must not perturb the
budgets): ``sum(node budgets) <= facility budget`` with worst-case
accounting — a node whose budget shrink is still in flight counts at its
OLD budget, exactly as an in-flight GPU cap lower counts at its old cap.

All nodes advance on one shared ``EventLoop``; arrivals enter through the
router (least marginal power-adjusted load against each node's *effective
role capacity*, so a hot-binned MI300X pool and a smaller H100 pool are
compared by real token rates) or pinned per node for heterogeneous / skewed
workload experiments. Role-flip completions travel back to the coordinator
as ``role_flip`` events published on the shared loop.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from repro.analysis.check.sanitize import InvariantSanitizer, sanitize_enabled
from repro.configs.base import ModelConfig
from repro.core.controller import (ControllerConfig, NodeStress, StaticPolicy)
from repro.core.costmodel import MI300X, GPUSpec
from repro.core.events import EventLoop
from repro.core.goodput import (EnergySignal, GoodputSummary, RequestRecord,
                                summarize)
from repro.core.power_model import PowerModel
from repro.core.prefixcache import PrefixCacheConfig
from repro.core.simulator import (NodeSimulator, SimRequest, Workload,
                                  build_request)
from repro.core.telemetry import TelemetryBus, TelemetryConfig
from repro.core.tenancy import TenantRegistry


@dataclasses.dataclass
class ClusterConfig:
    """Coordinator knobs (cluster-level analogue of ControllerConfig)."""
    period_s: float = 1.0           # coordinator tick
    shift_step_w: float = 200.0     # watts per node-budget move
    cooldown_s: float = 2.0         # between budget moves
    stress_gap: float = 0.25        # min (dst.stress - src.stress) to act
    dst_stress_min: float = 1.0     # sink must be (about to be) violating
    src_stress_max: float = 0.9     # source must be comfortably inside SLO
    allow_shift: bool = True        # False: static node budgets (baseline)
    allow_gpu_move: bool = False    # cluster-scale DynGPU (role flips)
    gpu_cooldown_s: float = 6.0     # between role flips (drain is costly)


@dataclasses.dataclass
class AdmissionConfig:
    """SLO-aware admission control (overload / emergency shedding).

    When ``slo_aware`` is on, the router projects each request's TTFT
    against the best available node *before* admitting it: requests whose
    projection comfortably fits the SLO are admitted; requests that would
    blow through it are *deferred* (retried after ``defer_s`` — queueing
    delay moves to the front door where it is visible and cancellable) and
    requests whose projection is hopeless even for their value class are
    *shed* outright. Shedding is biased by request value — decode-heavy
    requests (more output per unit of prefill cost, i.e. more goodput per
    joule) tolerate a proportionally higher projection before being shed,
    so under an emergency cap slash the fleet sheds the lowest-value work
    first instead of queueing everyone into violation. A deferred request
    keeps aging, so its projection only grows: every request terminally
    resolves to admitted or shed."""
    slo_aware: bool = False
    defer_s: float = 0.25           # retry delay for deferred requests
    defer_frac: float = 1.0         # admit while proj TTFT <= frac * SLO
    shed_frac: float = 2.0          # shed when proj TTFT > frac * SLO * value
    value_floor: float = 0.5        # clamp on the per-request value
    value_ceil: float = 2.0         # multiplier (vs trailing mean density)


class PowerAwareRouter:
    """Dispatch policies over the live node set:

    ``capacity`` (default) — least marginal power-adjusted load: (queued
    prefill tokens + this request's tokens) / effective prefill-role
    capacity, plus the queue-head-age early warning. Capacity-relative
    dispatch is what makes heterogeneous nodes and in-flight role flips
    route correctly — a node that just gained a prefill GPU (or has faster
    ones) absorbs proportionally more traffic.

    ``joules`` — least marginal joules per token (the per-request energy
    accounting's price signal, ``NodeSimulator.marginal_joules_per_token``):
    an energy-cost-aware fleet sends work where a token is cheapest — e.g.
    a TPU-v5e pool at 200 W beats an MI300X pool at 750 W when both have
    room. Equal prices (identical hardware at identical caps and batch)
    fall back to the capacity-relative load, so the policy degrades to
    ``capacity`` exactly when energy cannot distinguish the nodes.

    ``cost`` — least marginal *dollars* per token, latency-constrained:
    among the nodes whose load signal says this request would still meet
    its TTFT SLO with headroom, pick the cheapest joules weighted by the
    electricity price each node currently pays (``price_fn(node_id, now)``,
    e.g. per-facility tariff traces from ``core.autoscale.SignalTrace``);
    when no node has headroom, fall back to pure least-load. The latency
    filter is load-bearing: marginal joules per token *falls* as a decode
    batch fills (amortization), so ranking on price alone would pile every
    request onto the busiest node.

    ``affinity`` — session-locality routing over the capacity signal:
    subtract the request's *estimated* cached-prefix hit (tokens the
    target node's prefix cache would serve for free) from its marginal
    token load before ranking. The estimate comes from the router's OWN
    hint table — the last node each session path was routed to — never
    from reading node caches directly (the PR-9 telemetry-honesty rule:
    a stale hint degrades to a plain cache miss at prefill time, it never
    lies about capacity). Requests with no session path score identically
    to ``capacity``, so cold tenants are never starved by warm sessions.

    Ties (e.g. an idle homogeneous cluster) round-robin via a rotating
    start index so requests 0..k don't all pile onto node 0."""

    POLICIES = ("capacity", "joules", "cost", "affinity")

    def __init__(self, policy: str = "capacity",
                 price_fn: Optional[Callable[[int, float], float]] = None,
                 admission: Optional[AdmissionConfig] = None,
                 tenancy: Optional[TenantRegistry] = None):
        assert policy in self.POLICIES, policy
        self.policy = policy
        self.price_fn = price_fn
        self.adm = admission or AdmissionConfig()
        # tenant registry (core.tenancy): scales the admission value
        # density by tenant weight; None keeps pre-tenancy behaviour
        self.tenancy = tenancy
        # session-affinity hints: prefix path -> (node_id, cached tokens
        # last routed there). The router's private estimate of where each
        # session's KV lives — see the ``affinity`` policy note above.
        self._affinity: Dict[tuple, tuple] = {}
        # telemetry bus (set by ClusterSimulator): when present, all node
        # state reads go through it — sampled/degradable views instead of
        # omniscient direct reads. A fresh bus read is bit-identical to
        # the direct call, so standalone routers (no bus) behave the same.
        self.telemetry: Optional[TelemetryBus] = None
        self._rr = 0
        self.trace: List[tuple] = []    # (t, node_id)
        self.shed_trace: List[tuple] = []   # (t, rid, projected_ttft)
        self.defer_trace: List[tuple] = []  # (t, rid)
        # trailing mean of request value density, for the shed bias
        self._val_sum = 0.0
        self._val_n = 0

    def _price(self, node_id: int, now: float) -> float:
        if self.price_fn is None:
            return 1.0
        return max(self.price_fn(node_id, now), 0.0)

    def _load(self, nd: NodeSimulator, extra: int) -> float:
        """Node load signal through the telemetry bus when one is wired
        (fresh reads are bit-identical to the direct call)."""
        tb = self.telemetry
        return nd.router_load(extra) if tb is None else tb.router_load(
            nd, extra)

    def _jpt(self, nd: NodeSimulator, in_t: int, out_t: int) -> float:
        tb = self.telemetry
        return (nd.marginal_joules_per_token(in_t, out_t) if tb is None
                else tb.marginal_jpt(nd, in_t, out_t))

    def _hit_tokens(self, nd: NodeSimulator,
                    req: Optional[SimRequest]) -> int:
        """Estimated cached-prefix tokens ``req`` would hit on ``nd``,
        from the router's own hint table (longest matching prefix routed
        to that node). Zero for prefixless requests and unknown paths."""
        if req is None or not req.prefix_key:
            return 0
        path = req.prefix_key
        aff = self._affinity
        for k in range(len(path), 0, -1):
            hint = aff.get(path[:k])
            if hint is not None and hint[0] == nd.node_id:
                return min(hint[1], req.rec.input_tokens - 1)
        return 0

    def invalidate_affinity(self, node_id: int) -> None:
        """Drop every affinity hint pointing at ``node_id`` — its cache
        died with it (failure / power-off) or was cleared on rejoin; a
        stale hint would keep steering sessions at a cold node."""
        if self._affinity:
            self._affinity = {k: v for k, v in self._affinity.items()
                              if v[0] != node_id}

    def pick(self, now: float, nodes: Sequence[NodeSimulator],
             req: Optional[SimRequest] = None) -> NodeSimulator:
        k = self._rr % len(nodes)
        self._rr += 1
        order = list(nodes[k:]) + list(nodes[:k])
        extra = req.rec.input_tokens if req is not None else 0
        if self.policy in ("joules", "cost"):
            out = req.rec.output_tokens if req is not None else 256
            if self.policy == "cost":
                slo = req.rec.ttft_slo if req is not None else 1.0
                fits = [nd for nd in order
                        if self._load(nd, extra) <= 0.5 * slo]
                if fits:
                    node = min(fits, key=lambda nd: (
                        self._jpt(nd, extra, out)
                        * self._price(nd.node_id, now),
                        self._load(nd, extra)))
                else:
                    node = min(order, key=lambda nd: self._load(nd, extra))
            else:
                node = min(order, key=lambda nd: (
                    self._jpt(nd, extra, out),
                    self._load(nd, extra)))
        elif self.policy == "affinity":
            # the cached-prefix hit shrinks the request's marginal token
            # load on the node believed to hold its session KV; every
            # other signal (queue drain, head age) stays intact, so a
            # session only sticks while the warm node stays competitive
            node = min(order, key=lambda nd: self._load(
                nd, max(extra - self._hit_tokens(nd, req), 0)))
        else:
            node = min(order, key=lambda nd: self._load(nd, extra))
        if (self.policy == "affinity" and req is not None
                and req.prefix_key):
            self._affinity[req.prefix_key] = (
                node.node_id, min(sum(req.prefix_tokens),
                                  req.rec.input_tokens - 1))
        self.trace.append((now, node.node_id))
        return node

    def _density(self, req: SimRequest) -> float:
        """Value proxy: output tokens per total token moved — goodput per
        unit of serving cost — scaled by the tenant's admission weight
        when a registry is wired. Decode-heavy requests score higher;
        heavier tenants shed later."""
        total = req.rec.input_tokens + req.rec.output_tokens
        dens = req.rec.output_tokens / max(total, 1)
        if self.tenancy is not None:
            dens *= self.tenancy.weight(req.rec.tenant)
        return dens

    def decide(self, now: float, nodes: Sequence[NodeSimulator],
               req: SimRequest
               ) -> "tuple[str, Optional[NodeSimulator]]":
        """SLO-aware admission: returns ``("admit", node)``,
        ``("defer", None)`` or ``("shed", None)``. With admission control
        off this is exactly ``("admit", pick(...))`` — same trace, same
        rotation — so the default path is bit-identical to the pre-
        admission router."""
        if not self.adm.slo_aware:
            return "admit", self.pick(now, nodes, req)
        extra = req.rec.input_tokens
        best = min(self._load(nd, extra) for nd in nodes)
        if not (best < float("inf")):
            # every candidate momentarily unroutable (all draining): hold
            self.defer_trace.append((now, req.rid))
            return "defer", None
        # projected TTFT: time already lost waiting + the best node's
        # load signal (queue drain time for this request's tokens)
        proj = (now - req.rec.arrival) + best
        slo = req.rec.ttft_slo
        dens = self._density(req)
        if proj <= self.adm.defer_frac * slo:
            self._val_sum += dens
            self._val_n += 1
            return "admit", self.pick(now, nodes, req)
        mean = self._val_sum / self._val_n if self._val_n else dens
        value = min(max(dens / max(mean, 1e-9), self.adm.value_floor),
                    self.adm.value_ceil)
        if proj > self.adm.shed_frac * slo * value:
            self.shed_trace.append((now, req.rid, proj))
            return "shed", None
        self.defer_trace.append((now, req.rid))
        return "defer", None

    def decide_local(self, now: float, nodes: Sequence[NodeSimulator],
                     req: SimRequest
                     ) -> "tuple[str, Optional[NodeSimulator]]":
        """Headless fallback admission (controller crash window): no
        fleet-wide best-node scan — that ranking is the dead controller's
        job. Round-robin a node, then admit/defer/shed by that node's OWN
        live queue state, a purely local signal every node has without
        telemetry. Same thresholds and value-density bias as ``decide``,
        so shedding stays SLO-aware while headless; with admission control
        off this admits everything, like ``decide`` does."""
        k = self._rr % len(nodes)
        self._rr += 1
        node = nodes[k]
        if not self.adm.slo_aware:
            self.trace.append((now, node.node_id))
            return "admit", node
        load = node.router_load(req.rec.input_tokens)
        if not (load < float("inf")):
            self.defer_trace.append((now, req.rid))
            return "defer", None
        proj = (now - req.rec.arrival) + load
        slo = req.rec.ttft_slo
        dens = self._density(req)
        if proj <= self.adm.defer_frac * slo:
            self._val_sum += dens
            self._val_n += 1
            self.trace.append((now, node.node_id))
            return "admit", node
        mean = self._val_sum / self._val_n if self._val_n else dens
        value = min(max(dens / max(mean, 1e-9), self.adm.value_floor),
                    self.adm.value_ceil)
        if proj > self.adm.shed_frac * slo * value:
            self.shed_trace.append((now, req.rid, proj))
            return "shed", None
        self.defer_trace.append((now, req.rid))
        return "defer", None


class ClusterSimulator:
    """N ``NodeSimulator`` nodes on one clock under a facility power budget."""

    def __init__(self, cfg: ModelConfig, policy: StaticPolicy, n_nodes: int,
                 node_budget_w: float = 4800.0,
                 facility_budget_w: Optional[float] = None,
                 ctrl_cfg: Optional[ControllerConfig] = None,
                 cluster_cfg: Optional[ClusterConfig] = None,
                 gpu: GPUSpec = MI300X, power: Optional[PowerModel] = None,
                 coalesced: bool = False, seed: int = 0,
                 policies: Optional[Sequence[StaticPolicy]] = None,
                 node_budgets: Optional[Sequence[float]] = None,
                 gpu_specs: Optional[Sequence[GPUSpec]] = None,
                 powers: Optional[Sequence[PowerModel]] = None,
                 fidelity: str = "macro", router_policy: str = "capacity",
                 sanitize: Optional[bool] = None,
                 admission: Optional[AdmissionConfig] = None,
                 telemetry: Optional[TelemetryConfig] = None,
                 tenancy: Optional[TenantRegistry] = None,
                 cache_cfg: Optional[PrefixCacheConfig] = None):
        """``gpu_specs`` / ``powers``: per-node hardware for heterogeneous
        clusters (default: every node is ``gpu``; a ``None`` power entry
        resolves from the node's spec). When ``node_budgets`` is omitted,
        each node's default budget is clamped to its spec's cap envelope —
        so e.g. a TPU-v5e node (8 x 110–200 W) drops into an MI300X/H100
        cluster without hand-built per-node budgets. ``fidelity``:
        forwarded to every node — ``"macro"`` (default, event-coalesced
        decode) or ``"iter"`` (one event per decode iteration; the
        golden-equivalence path). ``router_policy``: see PowerAwareRouter.
        ``sanitize``: validate core invariants at every dispatch
        (default: the ``RAPID_SANITIZE`` environment variable).
        ``admission``: SLO-aware admission control / load shedding at the
        router front door (default off — see ``AdmissionConfig``).
        ``telemetry``: staleness bounds for the control-plane telemetry
        bus (see ``core.telemetry.TelemetryConfig``; the default bus is a
        bit-identical pass-through until a ``ChaosEngine`` degrades it).
        ``tenancy``: shared tenant registry (priority preemption on the
        nodes, weight-biased admission at the router, per-tenant
        attribution in the summary). ``cache_cfg``: build a per-node
        prefix cache (``core.prefixcache``); both default off, keeping
        single-stream runs on their exact pre-tenancy event sequence."""
        self.loop = EventLoop()
        if sanitize_enabled(sanitize):
            san = InvariantSanitizer()
            san.attach_cluster(self)
            self.loop.sanitizer = san
        pols = list(policies) if policies else [policy] * n_nodes
        specs = list(gpu_specs) if gpu_specs else [gpu] * n_nodes
        assert len(specs) == n_nodes
        pwrs = list(powers) if powers else [power] * n_nodes
        assert len(pwrs) == n_nodes
        if node_budgets:
            budgets = list(node_budgets)
        else:
            n_per = [p.n_prefill + p.n_decode for p in pols]
            budgets = [min(node_budget_w, n_per[i] * specs[i].max_cap_w)
                       for i in range(n_nodes)]
        assert len(budgets) == n_nodes
        self.facility_budget_w = facility_budget_w or float(sum(budgets))
        assert sum(budgets) <= self.facility_budget_w + 1e-6
        # effective facility limit: normally the nameplate budget; a power
        # emergency (core.fleet) slashes it for a window and restores it.
        # Every grant/headroom computation clamps against the limit; the
        # nameplate remains the hard conservation bound.
        self.facility_limit_w = self.facility_budget_w
        # an open emergency window: the coordinator holds its power plan
        self.emergency_hold = False
        self.n_shed = 0
        self.tenancy = tenancy
        self.nodes = [
            NodeSimulator(cfg, pols[i], node_budget_w=budgets[i],
                          gpu=specs[i], power=pwrs[i], ctrl_cfg=ctrl_cfg,
                          coalesced=coalesced, seed=seed + i, loop=self.loop,
                          node_id=i, fidelity=fidelity, sanitize=sanitize,
                          cache_cfg=cache_cfg, tenancy=tenancy)
            for i in range(n_nodes)
        ]
        self.fidelity = fidelity
        self.router = PowerAwareRouter(router_policy, admission=admission,
                                       tenancy=tenancy)
        # every controller on this cluster reads node state through the
        # bus; the chaos engine is the only writer of its fault hook
        self.telemetry = TelemetryBus(self, telemetry)
        self.router.telemetry = self.telemetry
        self.ccfg = cluster_cfg or ClusterConfig()
        self.records: List[RequestRecord] = []
        self.shift_trace: List[tuple] = []    # (t, src, dst, watts)
        self.budget_trace: List[tuple] = []   # (t, [budgets], total)
        self.flip_trace: List[tuple] = []     # (t, node_id, direction) starts
        self.flip_done_trace: List[tuple] = []  # (t, node_id, gid, new_role)
        self._inflight: set = set()           # node ids with a budget op
        self._last_shift_t = -1e9
        self._flip_node: Optional[int] = None   # node with a drain in flight
        self._last_flip_t = -1e9
        # fleet membership (core.fleet flips these): inactive nodes take no
        # routed traffic and no coordinator attention; a membership power
        # redistribution in flight pauses coordinator budget ops
        self.active: List[bool] = [True] * n_nodes
        self.churn_inflight = False
        # control-plane fault tolerance (core.telemetry / core.fleet):
        # while a scheduled controller crash window is open the cluster
        # runs headless — local admission, no coordinator decisions, and
        # every budget grant epoch-fenced. The epoch bumps at each restart
        # so grants issued by a dead incarnation cannot commit.
        self.controller_down = False
        self.controller_epoch = 0
        self.crash_trace: List[tuple] = []   # (t, "crash"|"restart", epoch)
        self.hold_trace: List[tuple] = []    # (t, reason, staleness_s)
        # committed grants: (t, src, dst, watts, epoch_issued, epoch_now,
        # controller_down) — the sanitizer audits the last two fields
        self.grant_trace: List[tuple] = []
        self.fence_trace: List[tuple] = []   # (t, src, dst, freed, epoch)
        self._ctrl_snapshot: Optional[tuple] = None
        # tariff inputs (set by core.autoscale, or directly): when present,
        # the summary prices spent joules into $/good-token and
        # gCO2/good-token alongside J/good-token
        self.price_trace: Optional[EnergySignal] = None
        self.carbon_trace: Optional[EnergySignal] = None
        self.loop.subscribe("role_flip", self._on_role_flip)

    def active_nodes(self) -> List[NodeSimulator]:
        return [nd for nd, a in zip(self.nodes, self.active) if a]

    # ---------------- invariants ----------------
    def assert_facility_invariant(self) -> None:
        """Worst-case facility accounting: in-flight budget shrinks count at
        the old (higher) budget, so this must hold at every instant.
        Powered-off nodes hold zero budget, so summing every node covers
        fleet membership changes too."""
        total = sum(nd.pm.budget for nd in self.nodes)
        assert total <= self.facility_budget_w + 1e-6, \
            (total, self.facility_budget_w)
        for nd in self.nodes:
            assert nd.pm._worst_case() <= nd.pm.budget + 1e-6, \
                (nd.node_id, nd.pm._worst_case(), nd.pm.budget)
        return total

    # ---------------- event handling ----------------
    def sync_all(self) -> None:
        """Bring every live node's macro-stepped iterations and power
        manager up to date (cross-node readers must not see stale state).
        Shared by cluster events and the fleet manager's churn/migration
        events."""
        if self.fidelity == "macro":
            for nd in self.nodes:
                if not nd.defunct:
                    nd.sync()

    def validate_all(self) -> None:
        """Post-event plan revalidation on every live node (cap changes this
        event made truncate running plans at the in-flight boundary)."""
        if self.fidelity == "macro":
            for nd in self.nodes:
                if not nd.defunct:
                    nd._validate_plans()

    def route(self, req: SimRequest) -> NodeSimulator:
        """Router dispatch over the active membership (fleet requeues and
        migrations re-enter through here too)."""
        return self.router.pick(self.loop.now, self.active_nodes(), req)

    def _handle(self, kind: str, payload=None):
        # cluster events read cross-node state (router loads, stress
        # summaries, facility accounting): bring every node's macro-stepped
        # iterations and power manager up to date first, and afterwards cut
        # short any plan whose GPU cap this event changed (budget grows
        # raise caps immediately; coordinator flips migrate batches).
        # Arrivals only read prefill-side queues (event-driven) plus power
        # caps, so the cheap power-only sync suffices for the router.
        now = self.loop.now
        if kind == "arrival":
            if self.fidelity == "macro":
                for nd in self.nodes:
                    if not nd.defunct:
                        nd.sync_power()
            req, node_id = payload
            if node_id is not None and not self.active[node_id]:
                node_id = None    # pinned to a node that left: re-route
            if node_id is None and not self.active_nodes():
                # whole fleet momentarily dark (churn window): hold the
                # arrival and retry, like the fleet's own requeue path
                self.loop.push(now + 0.25, self._handle, "arrival",
                               (req, None))
                return
            if node_id is not None:
                node = self.nodes[node_id]   # pinned traffic bypasses
            else:                            # admission control
                decide = (self.router.decide_local if self.controller_down
                          else self.router.decide)
                verdict, picked = decide(now, self.active_nodes(), req)
                if verdict == "shed":
                    self.mark_shed(req)
                    return
                if verdict == "defer":
                    self.loop.push(now + self.router.adm.defer_s,
                                   self._handle, "arrival", (req, None))
                    return
                assert picked is not None
                node = picked
            # announce the accepted arrival on the shared loop: the
            # autoscaler's forecaster (and any other observer) sees exactly
            # the stream the fleet admitted, at admission time — fleet
            # requeues/migrations re-enter elsewhere and are not arrivals
            self.loop.publish("arrival", req)
            node.handle("arrival", req)
        elif kind == "cluster_ctrl":
            self.sync_all()
            self._on_cluster_ctrl()
        elif kind == "budget_ready":
            self.sync_all()
            self._on_budget_ready(*payload)
        else:
            raise ValueError(f"unknown cluster event {kind!r}")
        self.validate_all()

    def _on_budget_ready(self, src_id: int, dst_id: int, freed: float,
                         epoch: int = 0):
        now = self.loop.now
        src, dst = self.nodes[src_id], self.nodes[dst_id]
        self._inflight.discard(src_id)
        self._inflight.discard(dst_id)
        if not src.pm.powered:
            # source failed mid-shift: its watts left with it (the fleet
            # redistributed them at the failure instant); nothing to hand on
            return
        src.pm.commit_budget(now)
        if epoch != self.controller_epoch or self.controller_down:
            # epoch fence: this grant was issued by a controller incarnation
            # that has since crashed (or the crash window is still open).
            # Fail safe: the source's cap lowering above still commits —
            # that is the guard band — but the freed watts are NOT granted
            # against a dead epoch; they sit as facility headroom until the
            # restarted controller's re-level reclaims them.
            self.fence_trace.append((now, src_id, dst_id, freed, epoch))
            self.assert_facility_invariant()
            return
        # the sink takes only what still fits under the *effective* limit:
        # an emergency that slashed the facility budget after this shift
        # was scheduled (and retargeted the source's shrink to its own,
        # tighter level) must not see the freed watts reappear on the sink.
        # With no emergency the headroom covers ``freed`` exactly and this
        # is the pre-existing grow/return-remainder flow, bit for bit.
        headroom = max(self.facility_limit_w
                       - sum(nd.pm.budget for nd in self.nodes), 0.0)
        grant = min(freed, headroom) if dst.pm.powered else 0.0
        absorbed = dst.pm.grow_budget(now, grant) if grant > 1e-12 else 0.0
        back = min(freed - absorbed,
                   max(self.facility_limit_w
                       - sum(nd.pm.budget for nd in self.nodes), 0.0))
        if back > 1e-9:
            # sink at its ceiling (or gone): return the remainder to the
            # source so facility watts are conserved
            src.pm.grow_budget(now, back)
        self.shift_trace.append((now, src_id, dst_id, absorbed))
        self.grant_trace.append((now, src_id, dst_id, absorbed, epoch,
                                 self.controller_epoch,
                                 self.controller_down))
        self.assert_facility_invariant()

    def _eligible_sources(self, stresses: List[NodeStress],
                          dst: NodeStress) -> List[NodeStress]:
        """Nodes that could give up a budget step right now: comfortably
        inside SLO, sufficiently less stressed than the sink, and above
        their budget floor."""
        c = self.ccfg
        return [s for s in stresses
                if s.node_id != dst.node_id
                and s.stress <= c.src_stress_max
                and dst.stress - s.stress >= c.stress_gap
                and (self.nodes[s.node_id].pm.budget - c.shift_step_w
                     >= self.nodes[s.node_id].pm.budget_floor_w - 1e-9)]

    def _fair_ceiling_w(self, node_id: int) -> float:
        """Most watts this node could ever hold under the facility budget:
        its own GPU-cap ceiling, or the facility minus every other *active*
        node's floor — whichever binds first. Powered-off nodes hold no
        watts, so elasticity raises every survivor's fair ceiling."""
        others_floor = sum(nd.pm.budget_floor_w for nd in self.active_nodes()
                           if nd.node_id != node_id)
        return min(self.nodes[node_id].pm.budget_ceil_w,
                   self.facility_limit_w - others_floor)

    def _watts_exhausted(self, stresses: List[NodeStress],
                         dst: NodeStress) -> bool:
        """True when budget shifting cannot relieve ``dst`` any further:
        shifting disabled, the sink already at its facility-fair ceiling,
        or no source node has watts to give."""
        if not self.ccfg.allow_shift:
            return True
        dst_nd = self.nodes[dst.node_id]
        if dst_nd.pm.budget >= self._fair_ceiling_w(dst.node_id) - 1e-6:
            return True
        return not self._eligible_sources(stresses, dst)

    def _try_budget_shift(self, now: float, stresses: List[NodeStress],
                          dst: NodeStress) -> bool:
        """MovePower at cluster scale: shrink the least-stressed eligible
        source's budget; watts land on the sink at ``budget_ready``."""
        c = self.ccfg
        dst_nd = self.nodes[dst.node_id]
        if dst_nd.pm.budget >= self._fair_ceiling_w(dst.node_id) - 1e-6:
            return False            # sink cannot absorb another step
        sources = self._eligible_sources(stresses, dst)
        if not sources:
            return False
        src = min(sources, key=lambda s: s.stress)
        t_ready, freed = self.nodes[src.node_id].pm.shrink_budget(
            now, c.shift_step_w)
        if freed <= 0:
            return False
        self._inflight.update((src.node_id, dst.node_id))
        self._last_shift_t = now
        # the grant rides with the epoch that issued it: if the controller
        # crashes before t_ready, the fence in _on_budget_ready voids it
        self.loop.push(t_ready, self._handle, "budget_ready",
                       (src.node_id, dst.node_id, freed,
                        self.controller_epoch))
        return True

    def _try_role_flip(self, now: float, stresses: List[NodeStress],
                       dst: NodeStress) -> bool:
        """MoveGPU at cluster scale: flip one GPU toward the role ``dst``
        is starved for, on the least-stressed node that can afford to lose
        one of the opposite role. The flip changes no budgets — the node
        re-levels its own caps after the drain — so the facility invariant
        must hold throughout; assert it at the start and (via the
        ``role_flip`` event) at the end of the drain."""
        direction = "d2p" if dst.hot_role == "prefill" else "p2d"
        for s in sorted(stresses, key=lambda s: s.stress):
            if self.nodes[s.node_id].request_role_flip(direction):
                self._flip_node = s.node_id
                self._last_flip_t = now
                self.flip_trace.append((now, s.node_id, direction))
                self.assert_facility_invariant()
                return True
        return False

    def _on_role_flip(self, payload):
        """A node completed a role flip: re-assert the facility invariant at
        the exact completion instant. Only coordinator-requested flips
        (``external=True``) clear the one-flip-at-a-time slot and land in
        ``flip_done_trace`` — a node controller's own concurrent role switch
        must not release the coordinator's in-flight drain early."""
        node_id, gid, new_role, external = payload
        if external:
            if self._flip_node == node_id:
                self._flip_node = None
            self.flip_done_trace.append(
                (self.loop.now, node_id, gid, new_role))
        self.assert_facility_invariant()

    def _on_cluster_ctrl(self):
        now = self.loop.now
        total = self.assert_facility_invariant()
        self.budget_trace.append(
            (now, [nd.pm.budget for nd in self.nodes], total))
        c = self.ccfg
        if self.controller_down:
            # headless window: the invariant probe above still records
            # (facility conservation stays auditable while nobody decides);
            # the tick keeps re-arming so the restarted controller resumes
            # without a fresh kick
            if self.loop.heap:
                self.loop.push(now + c.period_s, self._handle,
                               "cluster_ctrl")
            return
        # periodic control-state checkpoint: what restore_control rebuilds
        # the coordinator from after a crash (the autoscaler checkpoints
        # its own state through core.telemetry.ControlJournal)
        self._ctrl_snapshot = (now, self._last_shift_t, self._last_flip_t)
        live = self.active_nodes()
        if (c.allow_shift or c.allow_gpu_move) and live \
                and not self.churn_inflight and not self.emergency_hold:
            tb = self.telemetry
            stresses = [tb.stress(nd) for nd in live]
            stale_s = tb.max_staleness(live)
            if stale_s > tb.cfg.max_staleness_s:
                # the served views are older than the staleness bound:
                # hold the power plan on last-known-good state (fail-safe)
                # unless configured to act anyway (fig14's naive arm)
                self.hold_trace.append((now, "stale", stale_s))
                if not tb.cfg.act_on_stale:
                    if self.loop.heap:
                        self.loop.push(now + c.period_s, self._handle,
                                       "cluster_ctrl")
                    return
            dst = max(stresses, key=lambda s: s.stress)
            if dst.stress >= c.dst_stress_min:
                shifted = False
                if (c.allow_shift and not self._inflight
                        and now - self._last_shift_t >= c.cooldown_s):
                    shifted = self._try_budget_shift(now, stresses, dst)
                if (not shifted and c.allow_gpu_move
                        and self._flip_node is None
                        and now - self._last_flip_t >= c.gpu_cooldown_s
                        and self._watts_exhausted(stresses, dst)):
                    self._try_role_flip(now, stresses, dst)
        if self.loop.heap:
            self.loop.push(now + c.period_s, self._handle, "cluster_ctrl")

    def restore_control(self) -> None:
        """Rebuild coordinator state after a controller restart (the
        recovery protocol's cluster half): restore the cooldown clocks
        from the last periodic checkpoint — conservative, because the
        rebuilt controller cannot fire a shift earlier than the crashed
        one could have. Budget ops the crash orphaned need no repair
        here: their ``budget_ready`` events still dispatch, the epoch
        fence voids the grant, and the unconditional ``_inflight``
        discard clears the slot."""
        if self._ctrl_snapshot is not None:
            _t, last_shift, last_flip = self._ctrl_snapshot
            self._last_shift_t = last_shift
            self._last_flip_t = last_flip

    # ---------------- driving ----------------
    def mark_shed(self, req: SimRequest) -> None:
        """Admission control rejected this request: it will never finish
        (counts against SLO attainment) and its record carries the joules
        it burned before rejection. Run termination accounts for it."""
        req.rec.shed_t = self.loop.now
        self.n_shed += 1

    def _seed_arrivals(self, workload: Optional[Workload],
                       pinned: Optional[Dict[int, Workload]]):
        # start after any records pre-seeded before run() (e.g. a chaos
        # surge scheduled up front): rids must stay unique
        rid = len(self.records)
        streams = []
        if workload is not None:
            streams.append((None, workload))
        for node_id, wl in (pinned or {}).items():
            streams.append((node_id, wl))
        assert streams, "no workload given"
        for node_id, wl in streams:
            for entry in wl.entries:
                req = build_request(rid, entry)
                rid += 1
                self.records.append(req.rec)
                t = req.rec.arrival
                self.loop.push(t, self._handle, "arrival",
                               (req, node_id))

    def n_unfinished(self) -> int:
        # every record lands in exactly one node via submit(); counters keep
        # the per-event termination check O(n_nodes) with no record scans
        done = 0
        for nd in self.nodes:
            done += nd.finished_count
        # shed requests terminally resolved without finishing
        return len(self.records) - done - self.n_shed

    def run(self, workload: Optional[Workload] = None,
            pinned: Optional[Dict[int, Workload]] = None,
            horizon_s: float = 1e5) -> GoodputSummary:
        """``workload``: arrivals dispatched by the router. ``pinned``:
        {node_id: Workload} delivered to that node directly (skewed /
        heterogeneous per-node experiments). Both may be combined."""
        self._seed_arrivals(workload, pinned)
        for nd in self.nodes:
            nd.start()
        self.loop.push(0.0, self._handle, "cluster_ctrl")
        self.loop.run(lambda: self.n_unfinished() == 0, horizon_s)
        return self.summary()

    def summary(self) -> GoodputSummary:
        duration = max((r.finish or self.loop.now) for r in self.records) \
            if self.records else self.loop.now
        per_node_w = []
        for nd in self.nodes:
            if nd.power_samples:
                # stepwise time-weighted average over the run: a node that
                # the fleet powered off mid-run (its sample trail ends in a
                # 0 W mark) must not count as provisioned while dark —
                # that unprovisioned headroom is the elastic fleet's
                # qps-per-kW win. Before its first sample (standby joiner)
                # a node contributes nothing.
                total = 0.0
                samples = nd.power_samples
                for i, (t, w) in enumerate(samples):
                    t_next = samples[i + 1][0] if i + 1 < len(samples) \
                        else duration
                    total += w * max(t_next - t, 0.0)
                per_node_w.append(total / duration if duration > 0
                                  else samples[-1][1])
            else:
                per_node_w.append(sum(nd.pm.effective))
        return summarize(self.records, duration, float(sum(per_node_w)),
                         price_trace=self.price_trace,
                         carbon_trace=self.carbon_trace)

    def node_summaries(self) -> List[GoodputSummary]:
        return [nd.summary() for nd in self.nodes]

    def node_stresses(self) -> List[NodeStress]:
        return [nd.stress_summary() for nd in self.nodes]
