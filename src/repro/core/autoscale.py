"""Predictive standby-pool autoscaling and price/carbon-aware orchestration.

The fleet layer (``core.fleet``) *executes* membership changes — joins that
re-level facility watts, leaves that drain through KV-aware migration —
but until now the join/leave schedule was an operator-given input. This
module is the *decision* loop (ROADMAP item 2): a ``PredictiveAutoscaler``
that sits on ``FleetManager`` and drives membership from the workload and
the grid, so the objective the fleet optimizes becomes $/good-token and
gCO2/good-token, not just J/good-token.

Three pieces, all deterministic (no wall clock, no randomness — the golden
macro/iter equivalence tests run scenarios with the autoscaler active):

``SignalTrace``
    A piecewise-constant time series on the *simulation* clock —
    electricity price in $/kWh, grid carbon intensity in gCO2/kWh — given
    to the fleet as a first-class input. The autoscaler samples it at its
    decision ticks on the shared ``EventLoop``; ``goodput.summarize``
    prices every request's spent joules against it. Trace timestamps need
    not align with arrival timestamps: lookups clamp to the first/last
    segment, so a trace shorter than the simulated day simply holds its
    edge values.

``ArrivalForecaster``
    A trailing-window arrival-rate model: bucketed counts feed an EWMA
    level + trend, and when a seasonal period is configured (the diurnal
    day) a seasonal-naive term — the peak rate observed one period ago
    across the forecast window — takes over once a full season exists.
    Purely causal: it sees only arrivals with ``t <= now``, never the
    workload's future entries.

``PredictiveAutoscaler``
    The policy. Every ``period_s`` on the shared loop it compares demand
    (forecast rate over a ``lead_s`` horizon for mode ``"predictive"``;
    the current observed rate for ``"reactive"``) against the live
    membership's prefill capacity:

    * **ramp ahead**: demand above ``target_util`` of capacity powers a
      standby node on *before* the ramp arrives (``FleetManager.
      schedule_join`` — survivors shrink toward the uniform share first,
      source-before-sink), so prefill capacity is warm when load lands;
    * **trough consolidation**: demand below ``scale_down_util`` of the
      shrunken fleet's capacity drains the *worst* node — highest trailing
      ``energy_per_good_token_j``, price-weighted marginal joules as the
      tie-break — through the existing KV-aware migration path
      (``schedule_leave``), and its watts re-level across the survivors.

    Every decision is recorded in ``decision_trace`` with the signals it
    was made on (demand, capacity, price), so a benchmark or an operator
    can audit the loop after the fact.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterSimulator
from repro.core.fleet import FleetManager
from repro.core.simulator import NodeSimulator
from repro.core.telemetry import ControlJournal

J_PER_KWH = 3.6e6


class SignalTrace:
    """Piecewise-constant time series (electricity price, carbon intensity).

    ``values[i]`` holds from ``times[i]`` until ``times[i+1]``; lookups
    before the first knot return the first value and lookups past the last
    knot return the last value, so a trace covering less than the simulated
    horizon degrades to its edge values instead of raising — price-trace /
    arrival-trace timestamp misalignment is legal by construction.
    """

    def __init__(self, times: Sequence[float], values: Sequence[float],
                 name: str = "", units: str = ""):
        assert len(times) == len(values) and len(times) > 0, \
            "a trace needs at least one (time, value) knot"
        t = np.asarray(times, dtype=np.float64)
        assert bool(np.all(np.diff(t) >= 0.0)), "trace times must ascend"
        self.times = t
        self.values = np.asarray(values, dtype=np.float64)
        self.name = name
        self.units = units

    @classmethod
    def constant(cls, value: float, name: str = "",
                 units: str = "") -> "SignalTrace":
        """A flat trace (useful as a neutral price/carbon input)."""
        return cls([0.0], [value], name=name, units=units)

    def value_at(self, t: float) -> float:
        """Trace value in force at time ``t`` (edge-clamped)."""
        i = int(self.times.searchsorted(t, side="right")) - 1
        return float(self.values[max(i, 0)])

    def values_at(self, ts: np.ndarray) -> np.ndarray:
        """Vectorized ``value_at`` (edge-clamped), for summary pricing."""
        idx = self.times.searchsorted(ts, side="right") - 1
        return self.values[np.maximum(idx, 0)]

    def mean_over(self, t0: float, t1: float) -> float:
        """Time-weighted mean value over ``[t0, t1]`` (edge-clamped)."""
        if t1 <= t0:
            return self.value_at(t0)
        knots = self.times[(self.times > t0) & (self.times < t1)]
        edges = np.concatenate(([t0], knots, [t1]))
        vals = self.values_at(edges[:-1])
        return float(np.sum(vals * np.diff(edges)) / (t1 - t0))


class ArrivalForecaster:
    """Trailing-window arrival-rate forecaster (EWMA + seasonal-naive).

    Arrivals are counted into fixed ``bucket_s`` buckets; the trailing
    window keeps ``window_s`` worth of closed buckets. ``rate_now`` is the
    EWMA of closed-bucket rates (newest last). ``forecast`` extrapolates
    level + trend over the horizon and, when a seasonal period is set and a
    full period of history exists, defers to the seasonal-naive rate — the
    peak observed rate one season earlier across the forecast window —
    which is what sees a diurnal ramp *coming* rather than arriving.

    Deterministic and purely causal: state is only what ``observe`` was
    fed, and all of it carries simulation timestamps.
    """

    def __init__(self, bucket_s: float = 2.0, window_s: float = 60.0,
                 season_s: Optional[float] = None, alpha: float = 0.35):
        assert bucket_s > 0 and window_s >= bucket_s
        self.bucket_s = bucket_s
        self.window_s = window_s
        self.season_s = season_s
        self.alpha = alpha
        # trailing window of closed buckets: (bucket_index, count)
        self._buckets: List[Tuple[int, int]] = []
        self._cur_idx = 0
        self._cur_count = 0
        # seasonal history: bucket_index -> count, kept ~2 seasons deep
        self._season: dict = {}
        # trailing mean request shape (for capacity conversion)
        self._tok_sum = 0.0
        self._tok_n = 0

    def _roll(self, idx: int) -> None:
        """Close buckets up to (not including) bucket ``idx``."""
        if idx <= self._cur_idx:
            return
        if self._cur_count or self._buckets:
            self._buckets.append((self._cur_idx, self._cur_count))
            if self.season_s is not None and self._cur_count:
                self._season[self._cur_idx] = self._cur_count
        self._cur_idx = idx
        self._cur_count = 0
        keep = idx - int(math.ceil(self.window_s / self.bucket_s))
        while self._buckets and self._buckets[0][0] < keep:
            self._buckets.pop(0)
        if self.season_s is not None:
            horizon = idx - int(2 * self.season_s / self.bucket_s) - 1
            stale = [k for k in self._season if k < horizon]
            for k in stale:
                del self._season[k]

    def observe(self, t: float, in_tokens: int = 0) -> None:
        """Record one arrival at simulation time ``t``."""
        self._roll(int(t / self.bucket_s))
        self._cur_count += 1
        if in_tokens:
            self._tok_sum += in_tokens
            self._tok_n += 1

    @property
    def has_data(self) -> bool:
        """Whether any arrival has been observed at all. An autoscaler must
        not act on an empty window — a zero forecast before the first
        arrival is ignorance, not a trough."""
        return bool(self._buckets) or self._cur_count > 0

    def closed_buckets(self) -> int:
        """How many closed buckets the trailing window currently holds —
        the warmup gate: level/trend over one or two buckets is noise, and
        a trend extrapolated over a long horizon amplifies it."""
        return len(self._buckets)

    def mean_input_tokens(self, default: float = 2048.0) -> float:
        """Trailing mean prompt length (capacity conversion tokens->req/s)."""
        return self._tok_sum / self._tok_n if self._tok_n else default

    def _level_trend(self, now: float) -> Tuple[float, float]:
        self._roll(int(now / self.bucket_s))
        if not self._buckets:
            return 0.0, 0.0
        level = self._buckets[0][1] / self.bucket_s
        prev = level
        trend = 0.0
        for _, count in self._buckets[1:]:
            rate = count / self.bucket_s
            trend = (1 - self.alpha) * trend + self.alpha * (rate - prev)
            level = (1 - self.alpha) * level + self.alpha * rate
            prev = rate
        return level, trend / self.bucket_s   # trend per second

    def rate_now(self, now: float) -> float:
        """EWMA arrival rate (req/s) over the trailing window."""
        return self._level_trend(now)[0]

    def _seasonal_rate(self, t0: float, t1: float) -> Optional[float]:
        """Peak observed bucket rate one season before ``[t0, t1]``, or
        None if that span predates the history. Peak-seeking on purpose:
        a provisioning forecast answers "what is the largest rate this
        window will see", not "what is the average" — a mean would dilute
        a ramp that starts mid-horizon into looking serveable."""
        if self.season_s is None:
            return None
        lo = int((t0 - self.season_s) / self.bucket_s)
        hi = max(int(math.ceil((t1 - self.season_s) / self.bucket_s)), lo + 1)
        if lo < 0 or t0 < self.season_s:
            return None               # no full season observed yet
        peak = max(self._season.get(i, 0) for i in range(lo, hi))
        return peak / self.bucket_s

    def forecast(self, now: float, horizon_s: float) -> float:
        """Predicted mean arrival rate (req/s) over ``[now, now+horizon]``.

        Seasonal-naive (peak bucket rate one season earlier) once a full
        season of history covers the target window; EWMA level + trend
        extrapolation (floored at zero) otherwise. ``horizon_s=0``
        degrades to ``rate_now``.
        """
        level, trend = self._level_trend(now)
        seasonal = self._seasonal_rate(now, now + max(horizon_s,
                                                      self.bucket_s))
        if seasonal is not None:
            # blend: the season knows the shape, the EWMA knows today's
            # amplitude drift; weight the season fully at long horizons
            return max(seasonal, level + trend * horizon_s, 0.0) \
                if horizon_s > 0 else max(level, 0.0)
        return max(level + trend * horizon_s, 0.0)

    def state(self, now: float) -> tuple:
        """Canonical snapshot of the forecaster at ``now``. Buckets roll
        to ``now`` first, so two forecasters fed identical arrivals report
        identical state regardless of when each last rolled — the tuple is
        the golden recovery test's bit-identity gate, and what
        ``ControlJournal`` snapshots persist."""
        self._roll(int(now / self.bucket_s))
        return (tuple(self._buckets), self._cur_idx, self._cur_count,
                tuple(sorted(self._season.items())),
                self._tok_sum, self._tok_n)

    def load_state(self, state: tuple) -> None:
        """Restore a snapshot produced by ``state`` (controller restart:
        the recovery protocol loads this, then replays the journal)."""
        buckets, cur_idx, cur_count, season, tok_sum, tok_n = state
        self._buckets = list(buckets)
        self._cur_idx = cur_idx
        self._cur_count = cur_count
        self._season = dict(season)
        self._tok_sum = tok_sum
        self._tok_n = tok_n


@dataclasses.dataclass
class AutoscaleConfig:
    """Knobs for ``PredictiveAutoscaler`` (all times in sim seconds)."""
    mode: str = "predictive"        # "predictive" | "reactive" | "static"
    period_s: float = 2.0           # decision tick on the shared loop
    lead_s: float = 12.0            # scale-up look-ahead (predictive)
    target_util: float = 0.75       # scale up above this capacity fraction
    scale_down_util: float = 0.40   # consolidate below this (post-shrink)
    min_nodes: int = 1              # never drain below this many nodes
    holdoff_s: float = 10.0         # min spacing before a scale-down
    warmup_buckets: int = 3         # closed buckets required before acting
    bucket_s: float = 2.0           # forecaster bucket
    window_s: float = 60.0          # forecaster trailing window
    season_s: Optional[float] = None   # diurnal period, if known


class PredictiveAutoscaler:
    """Standby-pool autoscaler + price/carbon-aware orchestrator.

    Attaches to a ``FleetManager``; subscribes to the cluster's ``arrival``
    channel to feed its forecaster, ticks every ``cfg.period_s`` on the
    shared loop, and turns capacity pressure into fleet membership ops.
    ``price_trace``/``carbon_trace`` become the cluster's tariff inputs
    (``ClusterSimulator.summary`` then reports $/good-token and
    gCO2/good-token), and the scale-down choice is price-weighted: the
    node whose trailing SLO-good tokens were most expensive in joules
    drains first.

    Mode ``"static"`` keeps the machinery (ticks, traces, accounting) but
    never changes membership — the baseline arm of fig12.
    """

    def __init__(self, fleet: FleetManager,
                 cfg: Optional[AutoscaleConfig] = None,
                 price_trace: Optional[SignalTrace] = None,
                 carbon_trace: Optional[SignalTrace] = None):
        self.fm = fleet
        self.cs: ClusterSimulator = fleet.cs
        self.loop = fleet.loop
        self.cfg = cfg or AutoscaleConfig()
        assert self.cfg.mode in ("predictive", "reactive", "static"), \
            self.cfg.mode
        self.forecaster = ArrivalForecaster(
            bucket_s=self.cfg.bucket_s, window_s=self.cfg.window_s,
            season_s=self.cfg.season_s)
        self.price_trace = price_trace
        self.carbon_trace = carbon_trace
        # the traces are fleet-level inputs: the cluster summary prices
        # every record against them
        self.cs.price_trace = price_trace
        self.cs.carbon_trace = carbon_trace
        if price_trace is not None and self.cs.router.policy == "cost" \
                and self.cs.router.price_fn is None:
            # single-tariff fleet on the cost router: every node pays the
            # same trace (per-facility price_fns belong to multi-facility
            # setups and are passed to the router directly)
            def _price(node_id: int, t: float) -> float:
                return price_trace.value_at(t)
            self.cs.router.price_fn = _price
        self._last_action_t = -math.inf
        # (t, action, node_id, demand_rps, capacity_rps, price)
        self.decision_trace: List[tuple] = []
        self.signal_trace: List[tuple] = []   # (t, demand, capacity, price)
        self.loop.subscribe("arrival", self._on_arrival)
        # crash-recoverable coordination: the journal is the durable WAL
        # (it records arrivals even while the controller process is down);
        # each up-tick checkpoints controller state against it, and a
        # controller restart rebuilds from snapshot + replay
        self.journal = ControlJournal(self.loop)
        self.loop.subscribe("controller_restart", self._on_controller_restart)

    # ---------------- signals ----------------
    def _on_arrival(self, payload: object) -> None:
        if self.cs.controller_down:
            # the controller process is dead: it observes nothing. The
            # journal (durable, out-of-process) still records the arrival,
            # so recovery replays exactly what was missed.
            return
        rec = payload.rec if hasattr(payload, "rec") else payload
        self.forecaster.observe(self.loop.now, rec.input_tokens)

    def price_now(self) -> float:
        """Electricity price in force at the current sim time ($/kWh)."""
        return (self.price_trace.value_at(self.loop.now)
                if self.price_trace is not None else 0.0)

    def capacity_rps(self, nodes: Sequence[NodeSimulator]) -> float:
        """Aggregate prefill capacity of ``nodes`` in requests/s, at their
        *current* caps and the trailing mean prompt length. Read through
        the telemetry bus: a frozen pipeline serves last-known-good
        capacity, and the staleness hold in ``_tick`` decides whether the
        view is still actionable."""
        toks = self.forecaster.mean_input_tokens()
        tb = self.cs.telemetry
        return sum(tb.prefill_capacity_tps(nd)
                   for nd in nodes) / max(toks, 1.0)

    def demand_rps(self) -> float:
        """Demand signal per the configured mode: look-ahead forecast for
        ``predictive``, current observed rate otherwise."""
        now = self.loop.now
        if self.cfg.mode == "predictive":
            return self.forecaster.forecast(now, self.cfg.lead_s)
        return self.forecaster.rate_now(now)

    # ---------------- membership pools ----------------
    def _live(self) -> List[NodeSimulator]:
        return [nd for nd in self.cs.active_nodes()
                if not nd.leaving and not nd.defunct]

    def _standby(self) -> List[NodeSimulator]:
        return [nd for nd, act in zip(self.cs.nodes, self.cs.active)
                if not act and not nd.leaving
                and nd.node_id not in self.fm.pending_joins]

    def _drain_score(self, nd: NodeSimulator) -> Tuple[float, float, int]:
        """Ranking for trough power-off: worst trailing J/good-token first,
        price-weighted marginal joules as tie-break, node id last (total
        order — determinism)."""
        s = nd.summary()
        # joules spent with nothing good to show: the worst possible
        # efficiency, not the 0.0 the division fallback reports
        eff = (1e18 if s.total_energy_j > 0 and s.n_good == 0
               else s.energy_per_good_token_j)
        toks = self.forecaster.mean_input_tokens()
        marginal = self.cs.telemetry.marginal_jpt(nd, int(toks), 256)
        if not math.isfinite(marginal):
            marginal = 1e18
        # price-weight the prospective signal: at $0 the tie-break is pure
        # joules; under a live tariff it is the node's marginal $/token
        weight = max(self.price_now(), 1.0 / J_PER_KWH) / J_PER_KWH
        return (eff, marginal * weight, -nd.node_id)

    # ---------------- decision tick ----------------
    def start(self) -> None:
        """Arm the periodic decision tick (call before ``cluster.run``)."""
        self.loop.push(self.loop.now, self._handle, "autoscale")

    def _handle(self, kind: str, payload: object = None) -> None:
        assert kind == "autoscale", kind
        # same discipline as fleet/cluster events: this tick reads
        # cross-node state (capacities, trailing summaries), so macro
        # iterations materialize first and plans revalidate afterwards.
        # While the controller is crashed nothing decides and nothing
        # checkpoints, but the tick keeps re-arming so the restarted
        # controller resumes on schedule.
        if not self.cs.controller_down:
            self.cs.sync_all()
            self._tick()
            self.journal.snapshot(self._control_state())
            self.cs.validate_all()
        if self.loop.heap:
            self.loop.push(self.loop.now + self.cfg.period_s, self._handle,
                           "autoscale")

    def _tick(self) -> None:
        now = self.loop.now
        live = self._live()
        if not live or not self.forecaster.has_data:
            return                 # an empty window is ignorance, not load
        demand = self.demand_rps()
        cap = self.capacity_rps(live)
        price = self.price_now()
        self.signal_trace.append((now, demand, cap, price))
        if self.cfg.mode == "static":
            return
        if self.fm.emergency_active:
            # facility power emergency in force: membership changes are
            # frozen — a join would land on a slashed budget (deferred
            # anyway), and a drain-out would pile migration traffic onto a
            # fleet that is busy force-throttling. Hold until it clears.
            self.decision_trace.append(
                (now, "emergency_hold", -1, demand, cap, price))
            return
        tb = self.cs.telemetry
        stale_s = tb.max_staleness(live)
        if stale_s > tb.cfg.max_staleness_s and not tb.cfg.act_on_stale:
            # capacity views older than the staleness bound: joining or
            # draining against a frozen pipeline is guessing — hold on
            # last-known-good membership until telemetry recovers
            self.decision_trace.append(
                (now, "stale_hold", -1, demand, cap, price))
            return
        if self.forecaster.closed_buckets() < self.cfg.warmup_buckets:
            return                 # level/trend over <N buckets is noise
        if demand > self.cfg.target_util * cap:
            # scale-up is urgent — a steep ramp may need a node per tick,
            # so only the tick period and the one-join-in-flight rule
            # throttle it; ``holdoff_s`` protects the other direction
            self._scale_up(now, demand, cap, price)
        elif (now - self._last_action_t >= self.cfg.holdoff_s
              and len(live) > self.cfg.min_nodes):
            victim = max(live, key=self._drain_score)
            rest = [nd for nd in live if nd is not victim]
            shrunk = self.capacity_rps(rest)
            # scale down only if the *shrunken* fleet still clears the
            # scale-down watermark — hysteresis against flapping
            if demand < self.cfg.scale_down_util * shrunk:
                self._scale_down(now, victim, demand, shrunk, price)

    def _scale_up(self, now: float, demand: float, cap: float,
                  price: float) -> None:
        if self.fm.pending_joins:
            return                # one power-on handshake at a time
        standby = self._standby()
        if not standby:
            return
        # deterministic pick: lowest node id (homogeneous standby pool;
        # heterogeneous pools would rank by spec efficiency here)
        nid = min(standby, key=lambda nd: nd.node_id).node_id
        self.fm.schedule_join(now, nid)
        self._last_action_t = now
        self.decision_trace.append((now, "join", nid, demand, cap, price))

    def _scale_down(self, now: float, victim: NodeSimulator,
                    demand: float, shrunk_cap: float, price: float) -> None:
        self.fm.schedule_leave(now, victim.node_id)
        self._last_action_t = now
        self.decision_trace.append(
            (now, "leave", victim.node_id, demand, shrunk_cap, price))

    # ---------------- crash recovery ----------------
    def _control_state(self) -> tuple:
        """The controller state a restart must reproduce: the forecaster
        snapshot plus the action cooldown clock."""
        return (self.forecaster.state(self.loop.now), self._last_action_t)

    def _rebuild(self) -> Tuple[ArrivalForecaster, float]:
        """Reconstruct controller state from the last durable snapshot
        plus a replay of the journal entries recorded after it — the
        recovery protocol, exposed separately so the golden test can
        compare a rebuild against a live uncrashed controller bit for
        bit. Deterministic: forecaster state is a pure function of the
        observation stream, and snapshot + replay reproduces the stream
        exactly."""
        f = ArrivalForecaster(bucket_s=self.cfg.bucket_s,
                              window_s=self.cfg.window_s,
                              season_s=self.cfg.season_s)
        last_action = -math.inf
        n = 0
        snap = self.journal.latest()
        if snap is not None:
            _t, n, (fstate, last_action) = snap
            f.load_state(fstate)
        for (t, toks) in self.journal.replay_from(n):
            f.observe(t, toks)
        return f, last_action

    def _on_controller_restart(self, payload: object) -> None:
        """Crash recovery (published by ``FleetManager`` at the restart
        instant): rebuild the forecaster and cooldown clock; the next
        periodic tick decides on the rebuilt state."""
        f, last_action = self._rebuild()
        self.forecaster = f
        self._last_action_t = last_action
        self.decision_trace.append(
            (self.loop.now, "recovered", -1, 0.0, 0.0, self.price_now()))
