"""Shared discrete-event engine.

``NodeSimulator`` historically owned its own heap; the cluster layer needs
many nodes advancing on ONE clock so that router decisions, per-node
controllers, and cluster-level budget shifts interleave correctly. An
``EventLoop`` is that shared clock + heap: every participant pushes
``(time, handler, kind, payload)`` and the owner of the loop drives it.

Events at equal timestamps dispatch in push order (a monotonically
increasing sequence number breaks ties), which preserves the single-node
simulator's behaviour exactly when it owns a private loop.

Sanitizer mode: the loop optionally carries an ``InvariantSanitizer``
(``repro.analysis.check.sanitize``) which vets every ``push`` for
causality (no events in the past) and re-validates the registered
simulators' invariants after every dispatch. With ``sanitizer=None``
(the default) the residue is one ``is not None`` test per push/step.

The loop also carries a synchronous publish/subscribe channel: a node can
announce a state change (e.g. a role-flip drain starting or completing)
without knowing whether a cluster coordinator is listening. Subscribers run
inline at the publishing event's timestamp, so invariants can be asserted
at the exact instant the state changes.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, Dict, List, Optional


class EventLoop:
    # process-wide dispatch counter: benchmarks snapshot it around a run to
    # report how many events a figure cost (``benchmarks.common.Timer``)
    dispatched_total: int = 0

    def __init__(self, sanitizer: Optional[object] = None):
        self.heap: List[tuple] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.dispatched = 0            # events dispatched by *this* loop
        self._subs: Dict[str, List[Callable]] = {}
        self._cancelled: set = set()   # seq tokens of revoked events
        self.sanitizer = sanitizer     # InvariantSanitizer | None

    def subscribe(self, topic: str, fn: Callable[[object], None]) -> None:
        self._subs.setdefault(topic, []).append(fn)

    def publish(self, topic: str, payload: Any = None) -> None:
        for fn in self._subs.get(topic, []):
            fn(payload)

    def push(self, t: float, handler: Callable[[str, object], None],
             kind: str, payload: Any = None) -> int:
        """Schedule an event; returns a token accepted by ``cancel``."""
        if self.sanitizer is not None:
            self.sanitizer.check_push(self.now, t, kind)
        seq = next(self._seq)
        heapq.heappush(self.heap, (t, seq, kind, handler, payload))
        return seq

    def cancel(self, token: int) -> None:
        """Revoke a scheduled event by its ``push`` token. The heap entry
        stays (heaps cannot delete cheaply) but ``step`` discards it without
        dispatching — used for fallback timers that a faster completion path
        supersedes (e.g. a fleet leave-drain deadline)."""
        self._cancelled.add(token)

    def peek_time(self) -> Optional[float]:
        return self.heap[0][0] if self.heap else None

    def step(self) -> float:
        """Pop the next event, advance the clock, dispatch. Returns its time.
        Cancelled events advance the clock (their time has passed) but do
        not dispatch."""
        t, seq, kind, handler, payload = heapq.heappop(self.heap)
        self.now = t
        if self._cancelled:
            if seq in self._cancelled:
                self._cancelled.discard(seq)
                return t
        self.dispatched += 1
        EventLoop.dispatched_total += 1
        handler(kind, payload)
        if self.sanitizer is not None:
            self.sanitizer.after_dispatch(self)
        return t

    def run(self, until: Callable[[], bool], horizon_s: float = 1e5) -> None:
        """Drive events until ``until()`` is true, the heap empties, or the
        next event lies beyond ``horizon_s``."""
        while self.heap and not until():
            if self.heap[0][0] > horizon_s:
                break
            self.step()
