"""Multi-tenant SLO classes: per-tenant targets, priorities, and quotas.

RAPID's evaluation runs one anonymous request stream; production fleets
serve *tenants* — an interactive agent product, a batch summarization
pipeline, background evals — whose latency targets, business priorities,
and admission weights differ by orders of magnitude. This module is the
small, deliberately boring registry that makes tenants first-class:

* ``TenantSpec`` — one tenant's SLO class: TTFT/TPOT targets, an integer
  ``priority`` (higher preempts lower), and an admission ``weight`` that
  scales the request's value density in the router's SLO-aware shedding
  decision (``PowerAwareRouter._density``), so overload sheds background
  evals before it sheds interactive traffic.
* ``TenantRegistry`` — the lookup table every layer shares. Nodes consult
  it to decide whether an arriving request may preempt a running decode
  batch (``NodeSimulator._maybe_preempt``); the router consults it for
  admission weights; ``goodput.summarize`` attributes goodput, joules,
  dollars and grams of CO2 per tenant from the ``RequestRecord.tenant``
  tag alone.

The registry's tables (``_tenants``, ``_admitted``) are guarded by
simcheck RC007 the same way PowerManager budgets are guarded by RC001:
state may only change through the public API below, so per-tenant
accounting can be audited at two call sites instead of everywhere.

Determinism: the registry is a pure lookup table — no clocks, no
randomness — so threading it through the simulator preserves the
macro/iter bit-identity contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Tuple

DEFAULT_TENANT = "default"


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's SLO class.

    ``priority`` orders preemption (an arriving request may evict a
    running decode batch whose every member has strictly lower priority);
    ``weight`` scales the request's value density in SLO-aware admission,
    so shedding under overload is priority-shaped too.
    """
    name: str
    ttft_slo: float = 1.0
    tpot_slo: float = 0.040
    priority: int = 0
    weight: float = 1.0


class TenantRegistry:
    """Shared tenant lookup table (node preemption, router admission,
    per-tenant attribution).

    ``preempt`` is the subsystem's policy switch: with it ``False`` the
    priorities still shape admission weights and attribution, but no
    decode batch is ever evicted — the ``no_preempt`` ablation arm of
    ``benchmarks/fig15_multitenant.py``.
    """

    def __init__(self, specs: Iterable[TenantSpec] = (),
                 preempt: bool = True):
        self._tenants: Dict[str, TenantSpec] = {}
        self._admitted: Dict[str, int] = {}
        self.preempt = preempt
        self._default = TenantSpec(DEFAULT_TENANT)
        for spec in specs:
            self.register(spec)

    def register(self, spec: TenantSpec) -> None:
        """Add (or replace) one tenant's SLO class."""
        self._tenants[spec.name] = spec
        self._admitted.setdefault(spec.name, 0)

    def get(self, name: str) -> TenantSpec:
        """The tenant's spec; unknown tenants resolve to the neutral
        default class (priority 0, weight 1) so untagged traffic keeps
        its pre-tenancy behaviour."""
        return self._tenants.get(name, self._default)

    def priority(self, name: str) -> int:
        """Preemption priority of ``name`` (0 for unknown tenants)."""
        return self.get(name).priority

    def weight(self, name: str) -> float:
        """Admission weight of ``name`` (1.0 for unknown tenants)."""
        return self.get(name).weight

    def note_admit(self, name: str) -> None:
        """Count one admission against the tenant's quota ledger (the
        RC007-guarded write path for per-tenant counters)."""
        self._admitted[name] = self._admitted.get(name, 0) + 1

    def admitted(self) -> Dict[str, int]:
        """Per-tenant admission counts (a copy; the ledger itself only
        changes through ``note_admit``)."""
        return dict(self._admitted)

    def names(self) -> Tuple[str, ...]:
        """Registered tenant names, registration order."""
        return tuple(self._tenants)
