"""Goodput / SLO-attainment metrics (DistServe-style, per RAPID Section 3.1).

A request meets SLO iff TTFT <= ttft_slo AND mean TPOT <= tpot_slo.
Goodput = rate of SLO-meeting requests. QPS/W uses average *provisioned*
GPU power (the paper's accounting, Section 4).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class RequestRecord:
    rid: int
    arrival: float
    input_tokens: int
    output_tokens: int
    prefill_done: Optional[float] = None    # first token time
    finish: Optional[float] = None
    ttft_slo: float = 1.0
    tpot_slo: float = 0.040

    @property
    def ttft(self) -> Optional[float]:
        if self.prefill_done is None:
            return None
        return self.prefill_done - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        if self.finish is None or self.prefill_done is None:
            return None
        n = max(self.output_tokens - 1, 1)
        return (self.finish - self.prefill_done) / n

    @property
    def meets_slo(self) -> bool:
        return (self.ttft is not None and self.tpot is not None
                and self.ttft <= self.ttft_slo + 1e-9
                and self.tpot <= self.tpot_slo + 1e-9)


@dataclasses.dataclass
class GoodputSummary:
    n_total: int
    n_finished: int
    n_good: int
    slo_attainment: float          # fraction of all requests meeting SLO
    goodput_rps: float             # SLO-meeting requests per second
    p50_ttft: float
    p90_ttft: float
    p50_tpot: float
    p90_tpot: float
    duration_s: float
    avg_provisioned_w: float
    qps_per_kw: float

    def row(self) -> str:
        return (f"good {self.slo_attainment*100:5.1f}%  goodput "
                f"{self.goodput_rps:6.2f} req/s  TTFT p90 {self.p90_ttft:6.3f}s "
                f"TPOT p90 {self.p90_tpot*1e3:6.1f}ms  "
                f"QPS/kW {self.qps_per_kw:5.2f}")


def summarize(records: List[RequestRecord], duration_s: float,
              avg_provisioned_w: float) -> GoodputSummary:
    fin = [r for r in records if r.finish is not None]
    good = [r for r in fin if r.meets_slo]
    ttfts = np.array([r.ttft for r in fin]) if fin else np.array([np.inf])
    tpots = np.array([r.tpot for r in fin]) if fin else np.array([np.inf])
    goodput = len(good) / duration_s if duration_s > 0 else 0.0
    return GoodputSummary(
        n_total=len(records), n_finished=len(fin), n_good=len(good),
        slo_attainment=len(good) / max(len(records), 1),
        goodput_rps=goodput,
        p50_ttft=float(np.percentile(ttfts, 50)),
        p90_ttft=float(np.percentile(ttfts, 90)),
        p50_tpot=float(np.percentile(tpots, 50)),
        p90_tpot=float(np.percentile(tpots, 90)),
        duration_s=duration_s,
        avg_provisioned_w=avg_provisioned_w,
        qps_per_kw=1000.0 * goodput / max(avg_provisioned_w, 1.0),
    )
