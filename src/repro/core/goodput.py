"""Goodput / SLO-attainment metrics (DistServe-style, per RAPID Section 3.1).

A request meets SLO iff TTFT <= ttft_slo AND mean TPOT <= tpot_slo.
Goodput = rate of SLO-meeting requests. QPS/W uses average *provisioned*
GPU power (the paper's accounting, Section 4).

Per-request energy (``energy_j``): joules of *busy draw* integrated along
the request's prefill/decode path by the simulator — prefill batches split
proportionally by prompt tokens, decode iterations split evenly across the
batch. It counts work actually burned for the request (including work later
wasted by a node failure) but NOT idle/provisioned power — that overhead
lives in ``avg_provisioned_w``/``qps_per_kw``. ``energy_per_good_token_j``
divides fleet-wide spent energy by the output tokens of SLO-meeting
requests, so wasted work (failed/migrated/SLO-missing requests) makes the
goodput-relative energy price visibly worse.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol

import numpy as np

J_PER_KWH = 3.6e6


class EnergySignal(Protocol):
    """A time series the summary can price spent joules against — the
    structural type of ``core.autoscale.SignalTrace`` (price in $/kWh,
    carbon intensity in gCO2/kWh). Kept as a Protocol so this module stays
    below ``autoscale`` in the layering."""

    def values_at(self, ts: np.ndarray) -> np.ndarray:
        """Signal values in force at each timestamp (edge-clamped)."""
        ...


@dataclasses.dataclass
class RequestRecord:
    rid: int
    arrival: float
    input_tokens: int
    output_tokens: int
    prefill_done: Optional[float] = None    # first token time
    finish: Optional[float] = None
    ttft_slo: float = 1.0
    tpot_slo: float = 0.040
    energy_j: float = 0.0          # busy-draw joules spent on this request
    # SLO-aware admission control rejected this request (overload /
    # emergency shedding). A shed request can never finish or meet SLO —
    # it counts against attainment, and any joules it burned before being
    # shed (e.g. pre-failure work before a requeue was rejected) are
    # reported separately so degradation is visible, not laundered.
    shed_t: Optional[float] = None
    # owning tenant (core.tenancy): drives preemption priority, admission
    # weight, and the per-tenant attribution block in the summary. The
    # "default" tag keeps single-stream workloads on their pre-tenancy
    # accounting path.
    tenant: str = "default"

    @property
    def ttft(self) -> Optional[float]:
        if self.prefill_done is None:
            return None
        return self.prefill_done - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        if self.finish is None or self.prefill_done is None:
            return None
        n = max(self.output_tokens - 1, 1)
        return (self.finish - self.prefill_done) / n

    @property
    def meets_slo(self) -> bool:
        return (self.ttft is not None and self.tpot is not None
                and self.ttft <= self.ttft_slo + 1e-9
                and self.tpot <= self.tpot_slo + 1e-9)


@dataclasses.dataclass
class GoodputSummary:
    n_total: int
    n_finished: int
    n_good: int
    slo_attainment: float          # fraction of all requests meeting SLO
    goodput_rps: float             # SLO-meeting requests per second
    p50_ttft: float
    p90_ttft: float
    p50_tpot: float
    p90_tpot: float
    duration_s: float
    avg_provisioned_w: float
    qps_per_kw: float
    total_energy_j: float = 0.0
    # spent joules per SLO-meeting output token; 0.0 when nothing met SLO
    energy_per_good_token_j: float = 0.0
    # tariff attribution (0.0 unless price/carbon traces were provided):
    # spent joules priced at the electricity price / carbon intensity in
    # force when each request finished — the $/good-token and
    # gCO2/good-token objectives the autoscaler optimizes
    total_cost_usd: float = 0.0
    cost_per_good_token_usd: float = 0.0
    total_carbon_g: float = 0.0
    carbon_per_good_token_g: float = 0.0
    # load shedding (SLO-aware admission control): shed requests and the
    # joules they burned before rejection, accounted separately — they are
    # already counted against slo_attainment via n_total
    n_shed: int = 0
    shed_energy_j: float = 0.0
    # per-tenant attribution (core.tenancy): tenant name -> the same
    # goodput/energy/$/carbon metrics restricted to that tenant's records.
    # Empty for single-stream workloads, so existing JSON artifacts keep
    # their schema (append-only — old artifacts still parse).
    per_tenant: Dict[str, Dict[str, float]] = \
        dataclasses.field(default_factory=dict)

    def row(self) -> str:
        s = (f"good {self.slo_attainment*100:5.1f}%  goodput "
             f"{self.goodput_rps:6.2f} req/s  TTFT p90 {self.p90_ttft:6.3f}s "
             f"TPOT p90 {self.p90_tpot*1e3:6.1f}ms  "
             f"QPS/kW {self.qps_per_kw:5.2f}  "
             f"J/tok {self.energy_per_good_token_j:5.2f}")
        if self.total_cost_usd > 0.0:
            s += f"  $/Mtok {self.cost_per_good_token_usd*1e6:6.2f}"
        if self.total_carbon_g > 0.0:
            s += f"  gCO2/Mtok {self.carbon_per_good_token_g*1e6:6.1f}"
        if self.n_shed > 0:
            s += f"  shed {self.n_shed}"
        for name, t in self.per_tenant.items():
            s += (f"\n    {name:12s} good {t['slo_attainment']*100:5.1f}%  "
                  f"TTFT p90 {t['p90_ttft']:6.3f}s  "
                  f"J/tok {t['energy_per_good_token_j']:5.2f}")
            if t["total_cost_usd"] > 0.0:
                s += f"  $/Mtok {t['cost_per_good_token_usd']*1e6:6.2f}"
            if t["n_shed"] > 0:
                s += f"  shed {t['n_shed']:.0f}"
        return s


def summarize(records: List[RequestRecord], duration_s: float,
              avg_provisioned_w: float,
              price_trace: Optional[EnergySignal] = None,
              carbon_trace: Optional[EnergySignal] = None) -> GoodputSummary:
    # Vectorized over preallocated arrays: one attribute pass per record,
    # then numpy for TTFT/TPOT/SLO math — fleet-scale summaries (tens of
    # thousands of records) were a visible chunk of benchmark wall time.
    # The arithmetic mirrors RequestRecord.ttft/.tpot/.meets_slo exactly.
    n = len(records)
    arrival = np.empty(n)
    pd_ = np.empty(n)
    fin_t = np.empty(n)
    out_tok = np.empty(n)
    ttft_slo = np.empty(n)
    tpot_slo = np.empty(n)
    energy = np.empty(n)
    shed = np.empty(n, dtype=bool)
    tenants: List[str] = [""] * n
    for i, r in enumerate(records):
        arrival[i] = r.arrival
        pd_[i] = np.nan if r.prefill_done is None else r.prefill_done
        fin_t[i] = np.nan if r.finish is None else r.finish
        out_tok[i] = r.output_tokens
        ttft_slo[i] = r.ttft_slo
        tpot_slo[i] = r.tpot_slo
        energy[i] = r.energy_j
        shed[i] = r.shed_t is not None
        tenants[i] = r.tenant
    fin_mask = ~np.isnan(fin_t)
    n_fin = int(fin_mask.sum())
    ttft = pd_[fin_mask] - arrival[fin_mask]
    tpot = (fin_t[fin_mask] - pd_[fin_mask]) / \
        np.maximum(out_tok[fin_mask] - 1, 1)
    good_mask = ((ttft <= ttft_slo[fin_mask] + 1e-9) &
                 (tpot <= tpot_slo[fin_mask] + 1e-9) & ~np.isnan(ttft))
    n_good = int(good_mask.sum())
    if n_fin:
        p50_ttft, p90_ttft = np.percentile(ttft, (50, 90))
        p50_tpot, p90_tpot = np.percentile(tpot, (50, 90))
    else:
        # percentile() of [inf] raises a spurious inf-inf RuntimeWarning
        p50_ttft = p90_ttft = p50_tpot = p90_tpot = np.inf
    goodput = n_good / duration_s if duration_s > 0 else 0.0
    total_energy = float(energy.sum())
    good_tokens = float(out_tok[fin_mask][good_mask].sum())
    # tariff attribution: a record's joules are priced at the trace value
    # in force at its finish instant (arrival for never-finished requests —
    # their partial work was spent around then). Piecewise-constant traces
    # make this deterministic and cheap; sub-request price changes are
    # below the tariff resolution this models (5-minute to hourly markets).
    t_spend = np.where(np.isnan(fin_t), arrival, fin_t)
    total_cost = cost_per_good = 0.0
    cost = None
    if price_trace is not None:
        cost = energy / J_PER_KWH * price_trace.values_at(t_spend)
        total_cost = float(cost.sum())
        cost_per_good = total_cost / good_tokens if good_tokens > 0 else 0.0
    total_carbon = carbon_per_good = 0.0
    carbon = None
    if carbon_trace is not None:
        carbon = energy / J_PER_KWH * carbon_trace.values_at(t_spend)
        total_carbon = float(carbon.sum())
        carbon_per_good = (total_carbon / good_tokens
                           if good_tokens > 0 else 0.0)
    # per-tenant attribution: the same masks restricted per tenant tag.
    # Only materialized when the workload is actually multi-tenant, so
    # single-stream summaries (and their JSON artifacts) are unchanged.
    per_tenant: Dict[str, Dict[str, float]] = {}
    if any(t != "default" for t in tenants):
        good_full = np.zeros(n, dtype=bool)
        good_full[np.nonzero(fin_mask)[0]] = good_mask
        ttft_full = np.full(n, np.nan)
        ttft_full[fin_mask] = ttft
        tarr = np.array(tenants)
        for name in sorted(set(tenants)):
            m = tarr == name
            mf = m & fin_mask
            good_m = good_full & m
            n_good_m = int(good_m.sum())
            gtok = float(out_tok[good_m].sum())
            e_m = float(energy[m].sum())
            c_m = float(cost[m].sum()) if cost is not None else 0.0
            g_m = float(carbon[m].sum()) if carbon is not None else 0.0
            per_tenant[name] = {
                "n_total": int(m.sum()),
                "n_finished": int(mf.sum()),
                "n_good": n_good_m,
                "slo_attainment": n_good_m / max(int(m.sum()), 1),
                "goodput_rps": (n_good_m / duration_s
                                if duration_s > 0 else 0.0),
                "p90_ttft": (float(np.percentile(ttft_full[mf], 90))
                             if int(mf.sum()) else float(np.inf)),
                "total_energy_j": e_m,
                "energy_per_good_token_j": e_m / gtok if gtok > 0 else 0.0,
                "total_cost_usd": c_m,
                "cost_per_good_token_usd": c_m / gtok if gtok > 0 else 0.0,
                "total_carbon_g": g_m,
                "carbon_per_good_token_g": g_m / gtok if gtok > 0 else 0.0,
                "n_shed": int(shed[m].sum()),
            }
    return GoodputSummary(
        n_total=n, n_finished=n_fin, n_good=n_good,
        slo_attainment=n_good / max(n, 1),
        goodput_rps=goodput,
        p50_ttft=float(p50_ttft),
        p90_ttft=float(p90_ttft),
        p50_tpot=float(p50_tpot),
        p90_tpot=float(p90_tpot),
        duration_s=duration_s,
        avg_provisioned_w=avg_provisioned_w,
        qps_per_kw=1000.0 * goodput / max(avg_provisioned_w, 1.0),
        total_energy_j=total_energy,
        energy_per_good_token_j=(total_energy / good_tokens
                                 if good_tokens > 0 else 0.0),
        total_cost_usd=total_cost,
        cost_per_good_token_usd=cost_per_good,
        total_carbon_g=total_carbon,
        carbon_per_good_token_g=carbon_per_good,
        n_shed=int(shed.sum()),
        shed_energy_j=float(energy[shed].sum()),
        per_tenant=per_tenant,
    )
