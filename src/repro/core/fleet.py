"""Elastic fleet management: node churn, cross-node request migration, and
facility-level power redistribution on top of ``ClusterSimulator``.

The cluster layer (``core.cluster``) manages a FIXED node set: the
coordinator moves watts and roles between nodes that are always there. Real
fleets are elastic — nodes join (capacity brought online for a peak), leave
(maintenance windows), and fail (abruptly, with state loss) — and RAPID's
DISTRIBUTEUNIFORMPOWER step implicitly assumes the facility can re-level
watts whenever membership changes. ``FleetManager`` closes that gap with
three mechanisms, all scheduled as events on the cluster's shared loop:

**Membership churn.** ``schedule_join/leave/fail`` place churn events on
the event loop. A *join* runs facility-level DISTRIBUTEUNIFORMPOWER through
the PowerManager's hierarchical budget ops with the same source-before-sink
discipline the coordinator uses: survivors ``shrink_budget`` toward the new
uniform share first, and only when those shrinks are in force does the
commit release the watts that ``power_on`` the joiner. A *leave* drains the
node — queued work re-routes for free, KV-holding work migrates — then
powers it off and re-levels its watts across the survivors (raise-only:
freed watts cannot violate the facility cap). A *fail* is abrupt: every
request the node held (including those living only in event payloads —
in-flight prefill batches and ring transfers) loses its KV and re-enters
through the router from scratch. The facility invariant
``sum(node budgets) <= facility budget`` is asserted across every one of
these transitions, with in-flight shrinks counted at their old budgets.

**KV-aware migration.** A live decode request carries KV cache that is
expensive to move: ``kv_bytes_per_token * (prompt + generated)`` over the
cross-node interconnect (``GPUSpec.node_link_bw``). Migration is
drain→transfer→resume: the request leaves its batch at an iteration
boundary (with exact token/energy folds, and the macro plan truncated at
the in-flight iteration), the transfer occupies ``kv_migrate_time``, and on
arrival the request joins the least-saturated decode pool
(``adopt_decode``), retrying while pools are full. This is what lets the
coordinator flip roles on nodes carrying *pinned-only* traffic: the last
decode GPU on a node may flip to prefill because its batch can leave.

**Per-request energy accounting** (``core.simulator``) rides along: every
record accumulates busy-draw joules over its actual path — including work a
failure threw away — so the fleet's ``energy_per_good_token_j`` exposes the
true energy price of churn handling strategies.

``FleetConfig(elastic=False)`` is the baseline arm for the fig11
experiment: churn still happens (it is the environment, not a policy), but
leaves are handled like failures (no migration — in-flight work re-enters
from scratch) and the departed node's watts stay stranded instead of being
redistributed.

**Graceful degradation (chaos paths).** The same machinery absorbs the
fault scenarios ``core.chaos.ChaosEngine`` injects:

* *Facility power emergencies* — ``schedule_emergency`` slashes the
  facility's effective limit (``ClusterSimulator.facility_limit_w``) and
  force-throttles every powered node toward the uniform share of the
  emergency limit through ``PowerManager.emergency_shrink`` —
  source-before-sink: caps cut first, watts released at the commit once
  the lowered caps are in force. Join commits landing mid-emergency clamp
  their grant against the *limit*, not the nameplate budget. On clear the
  freed headroom re-levels back across the survivors.
* *Correlated (rack-scope) failures* — ``schedule_fail_group`` fails k
  co-located nodes in one instant and re-levels the facility ONCE with
  the pooled released watts, instead of k sequential redistributions.
* *Migration-link faults* — every KV transfer runs over the source
  node's shared outbound link (a per-node link clock: concurrent drain
  transfers *pipeline* back-to-back over ``node_link_bw``, paying the
  fixed RPC setup once per burst). A transfer the chaos engine fails
  retries with capped exponential backoff against a per-request
  deadline; past the deadline (or the retry budget) it falls back to
  requeue-with-KV-loss — the failure path. A stalled link delays the
  whole burst behind it. KV single-residency holds throughout: a request
  mid-transfer lives only in the migration ticket (zero residency).
* *Overload* — requeues re-enter through the router's SLO-aware
  admission control (``PowerAwareRouter.decide``), so a requeue storm
  into an emergency-shrunk fleet sheds instead of queueing everyone into
  violation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis.check.sanitize import InvariantSanitizer, sanitize_enabled
from repro.core.cluster import ClusterSimulator
from repro.core.simulator import NodeSimulator, SimRequest


@dataclasses.dataclass
class FleetConfig:
    elastic: bool = True            # False: no migration, no redistribution
    redistribute: bool = True       # facility re-level on churn (elastic)
    migrate_latency_s: float = 0.002   # per-burst fixed setup (RPC); drain
    #                                 transfers pipelining behind a burst
    #                                 head pay it once
    requeue_latency_s: float = 0.25    # client retry after a node failure
    adopt_retry_s: float = 0.02     # decode pools saturated: retry placement
    drain_grace_s: float = 10.0     # leave deadline; then remaining work
    #                                 is failed out (maintenance is a hard
    #                                 window, not a suggestion)
    # -- migration retry/timeout/backoff (chaos link faults) --
    migrate_max_retries: int = 4    # 0: first fault = immediate KV loss
    migrate_backoff_s: float = 0.05    # base retry delay, doubles per try
    migrate_backoff_cap_s: float = 0.8
    migrate_deadline_s: float = 8.0    # per-request migration deadline;
    #                                 past it the KV is written off and the
    #                                 request requeues from scratch


@dataclasses.dataclass(eq=False)
class _Migration:
    """One in-flight KV transfer (identity semantics: the ticket travels
    through retry events). The request it carries has ZERO residency —
    it lives only here until ``migrate_arrive`` adopts it or the deadline
    writes its KV off."""
    req: SimRequest
    src_id: int
    reason: str
    ctx: int
    dt: float              # pure transfer time over node_link_bw
    deadline: float        # absolute; requeue-with-KV-loss past this
    attempt: int = 0


class FleetManager:
    """Elastic membership for a ``ClusterSimulator``.

    Attaches as every node's ``migrator`` hook, so nodes hand over requests
    they can no longer serve (leave drains, full-prefill role flips, ring
    transfers landing on a decode-less node) without knowing where the work
    goes. All fleet actions that change power caps run as fleet events on
    the shared loop — wrapped in the same sync/validate discipline as
    cluster events, so macro-stepped decode plans are cut at churn and
    migration boundaries exactly where the per-iteration path would re-read
    the world (``fidelity="iter"`` and ``"macro"`` stay bit-identical
    through every join, leave, failure, and migration)."""

    def __init__(self, cluster: ClusterSimulator,
                 cfg: Optional[FleetConfig] = None,
                 standby: Sequence[int] = (),
                 sanitize: Optional[bool] = None):
        for nd in cluster.nodes:
            assert not nd.coalesced, "fleet churn needs disaggregated nodes"
        self.cs = cluster
        self.loop = cluster.loop
        self.cfg = cfg or FleetConfig()
        if self.loop.sanitizer is None and sanitize_enabled(sanitize):
            # the cluster was built without sanitize; honour an explicit
            # fleet-level request by installing one now
            san = InvariantSanitizer()
            san.attach_cluster(cluster)
            self.loop.sanitizer = san
        if self.loop.sanitizer is not None:
            self.loop.sanitizer.attach_fleet(self)
        # nameplate budgets: what each node held at construction — the
        # static arm re-powers a returning node at its nameplate (nobody
        # re-leveled anything while it was away)
        self._nameplate: Dict[int, float] = {
            nd.node_id: nd.pm.budget for nd in cluster.nodes}
        self._outbound: Dict[int, int] = {}   # node -> in-flight migrations
        self._force_tokens: Dict[int, int] = {}   # leave deadline events
        self.churn_trace: List[tuple] = []    # (t, kind, node_id)
        self.migration_trace: List[tuple] = []  # (t, rid, src, reason, ctx)
        self.requeue_trace: List[tuple] = []    # (t, rid, src)
        # -- chaos / degradation state --
        # per-source-node outbound link clock: the time the shared link is
        # busy until — drain bursts pipeline behind it over node_link_bw
        self._link_free: Dict[int, float] = {}
        # the ONE sanctioned fault-injection point (simcheck RC006): the
        # chaos engine installs a callable (src_id, t_start, dt) ->
        # None | ("stall", t_resume) | ("fail", t_fail)
        self.link_fault_fn: Optional[
            Callable[[int, float, float],
                     Optional[Tuple[str, float]]]] = None
        self.retry_trace: List[tuple] = []    # (t, rid, src, attempt)
        self.kv_loss_trace: List[tuple] = []  # (t, rid, src, why)
        self.stall_trace: List[tuple] = []    # (t, rid, src, resume_t)
        self.emergency_trace: List[tuple] = []  # (t, kind, limit_w)
        self.emergency_active = False   # an emergency window is open
        self._emergency_enforced = False  # shrinks committed, caps in force
        self._emergency_gen = 0         # guards commit racing a restore
        self._emergency_fracs: List[float] = []  # open windows; min() wins
        # joins dispatched but not yet activated: the autoscaler must not
        # double-join a node whose power-on handshake is still in flight
        self.pending_joins: set = set()
        # -- control-plane fault tolerance (core.telemetry) --
        # the heartbeat failure detector attaches itself here; without one,
        # only the oracle fail path (schedule_fail) detects deaths
        self.detector: Optional[object] = None
        self._suspected: set = set()     # de-routed, KV intact
        # physically-dead nodes the control plane has NOT detected yet:
        # their evicted requests and released watts sit here until the
        # failure detector's dead verdict recovers them (knowledge-gated —
        # the fleet cannot react to a death it hasn't observed)
        self._limbo: Dict[int, List[SimRequest]] = {}
        self._limbo_watts: Dict[int, float] = {}
        for nd in cluster.nodes:
            nd.migrator = self._migrate_out
        released = 0.0
        for nid in standby:
            cluster.active[nid] = False
            released += cluster.nodes[nid].pm.power_off(0.0)
            cluster.nodes[nid].power_samples.append((0.0, 0.0))
        if released > 0 and self.cfg.elastic and self.cfg.redistribute:
            # a standby pool is provisioned dark: its watts re-level across
            # the initially-active membership (raise-only — same path as a
            # leave), so a 2-of-4 fleet starts with the facility's watts
            # concentrated on the nodes actually serving
            self._grow_survivors(released)

    # ---------------- schedule API ----------------
    # Callers pass wall-plan times that may already have passed once the
    # sim is running (e.g. scripting churn mid-run); clamp to ``now`` so a
    # stale plan degrades to "immediately" instead of violating causality
    # on the shared clock (simcheck RC004).
    def schedule_join(self, t: float, node_id: int) -> None:
        self.loop.push(max(t, self.loop.now), self._handle, "join", node_id)

    def schedule_leave(self, t: float, node_id: int) -> None:
        self.loop.push(max(t, self.loop.now), self._handle, "leave", node_id)

    def schedule_fail(self, t: float, node_id: int) -> None:
        self.loop.push(max(t, self.loop.now), self._handle, "fail", node_id)

    def schedule_die(self, t: float, node_id: int) -> None:
        """Physical node death WITHOUT oracle detection: the node stops
        (KV gone, heartbeats cease, watts dark) but the fleet does NOT
        requeue or re-level — recovery waits for the heartbeat detector's
        dead verdict (``declare_dead``). This is the non-oracle sibling of
        ``schedule_fail``; it requires a ``HeartbeatDetector`` or the
        stranded work never recovers."""
        self.loop.push(max(t, self.loop.now), self._handle, "die", node_id)

    def schedule_controller_crash(self, t: float,
                                  duration_s: float) -> None:
        """Coordinator + autoscaler crash for ``duration_s``: the cluster
        runs headless (local caps, local admission, epoch-fenced grants)
        until the restart rebuilds controller state from snapshot +
        journal replay. Overlapping crash windows coalesce into the
        first."""
        t0 = max(t, self.loop.now)
        self.loop.push(t0, self._handle, "ctrl_crash", duration_s)

    def schedule_fail_group(self, t: float,
                            node_ids: Sequence[int]) -> None:
        """Correlated (rack-scope) failure: every listed node dies in the
        same instant, and the facility re-levels ONCE with the pooled
        released watts — not once per node."""
        self.loop.push(max(t, self.loop.now), self._handle, "fail_group",
                       tuple(node_ids))

    def schedule_emergency(self, t: float, frac: float,
                           duration_s: Optional[float] = None) -> None:
        """Facility power emergency: at ``t`` the facility's effective
        limit drops to ``frac`` of the nameplate budget for ``duration_s``
        seconds (indefinitely if ``None`` — cleared by a later overlapping
        schedule restoring it). Overlapping emergencies: the tighter limit
        wins while both are open."""
        assert 0.0 < frac <= 1.0
        t0 = max(t, self.loop.now)
        self.loop.push(t0, self._handle, "emergency_begin", frac)
        if duration_s is not None:
            self.loop.push(max(t0 + duration_s, self.loop.now),
                           self._handle, "emergency_end", frac)

    # ---------------- event plumbing ----------------
    def _handle(self, kind: str, payload=None):
        # fleet events read and mutate cross-node state: same discipline as
        # cluster events — materialize macro iterations first, truncate any
        # plan whose caps this event changed afterwards
        self.cs.sync_all()
        if kind == "join":
            self._on_join(payload)
        elif kind == "join_commit":
            self._on_join_commit(*payload)
        elif kind == "leave":
            self._on_leave(payload)
        elif kind == "leave_check":
            self._on_leave_check(payload)
        elif kind == "leave_force":
            self._on_leave_force(payload)
        elif kind == "fail":
            self._on_fail(payload)
        elif kind == "fail_group":
            self._on_fail_group(payload)
        elif kind == "die":
            self._on_die(payload)
        elif kind == "ctrl_crash":
            self._on_ctrl_crash(payload)
        elif kind == "ctrl_restart":
            self._on_ctrl_restart(payload)
        elif kind == "migrate_arrive":
            self._on_migrate_arrive(payload)
        elif kind == "migrate_fail":
            self._on_migrate_fail(payload)
        elif kind == "migrate_retry":
            self._start_transfer(payload)
        elif kind == "adopt_retry":
            self._try_adopt(payload)
        elif kind == "requeue":
            self._on_requeue(payload)
        elif kind == "regrow":
            self._grow_survivors(payload)
        elif kind == "emergency_begin":
            self._on_emergency_begin(payload)
        elif kind == "emergency_commit":
            self._on_emergency_commit(*payload)
        elif kind == "emergency_end":
            self._on_emergency_end(payload)
        else:
            raise ValueError(f"unknown fleet event {kind!r}")
        self.cs.validate_all()

    # ---------------- migration engine ----------------
    def _migrate_out(self, reqs: List[SimRequest], node: NodeSimulator,
                     has_kv: bool, reason: str):
        """Node-side hook (``NodeSimulator.migrator``): take over requests
        the node cannot serve. Runs inside node event handlers, so it only
        *schedules* — target selection, adoption, and any cap changes happen
        in fleet events with full sync/validate wrapping."""
        now = self.loop.now
        for req in reqs:
            node.release_record(req)
            if not has_kv:
                # never prefilled: re-routing costs nothing but the queue
                self.requeue_trace.append((now, req.rid, node.node_id))
                self.loop.push(now, self._handle, "requeue", req)
                continue
            ctx = req.rec.input_tokens + req.tokens_out
            if (node.prefix_cache is not None and req.prefix_key
                    and node.cache_cfg.carry_on_migrate):
                # detach the request's own session leaf to travel with its
                # KV (None if the leaf is shared or not resident); it rides
                # the migration ticket with zero cache residency and lands
                # via adopt_decode, or dies with the KV on requeue
                req.carried_block = node.prefix_cache.pop_leaf(
                    req.prefix_key)
            self._outbound[node.node_id] = \
                self._outbound.get(node.node_id, 0) + 1
            self.migration_trace.append(
                (now, req.rid, node.node_id, reason, ctx))
            self._start_transfer(_Migration(
                req=req, src_id=node.node_id, reason=reason, ctx=ctx,
                dt=node.cost.kv_migrate_time(ctx),
                deadline=now + self.cfg.migrate_deadline_s))
        if node.leaving:
            self.loop.push(now, self._handle, "leave_check", node.node_id)

    def _start_transfer(self, mig: _Migration) -> None:
        """Put one KV transfer on the source node's shared outbound link.
        Transfers pipeline: a burst of drain migrations queues back-to-back
        over ``node_link_bw``, paying the fixed RPC setup once at the burst
        head (an idle link) instead of once per request. The chaos engine's
        ``link_fault_fn`` (if installed) may fail or stall the slot."""
        now = self.loop.now
        free = self._link_free.get(mig.src_id, 0.0)
        if free <= now + 1e-12:
            start = now + self.cfg.migrate_latency_s   # burst head: RPC setup
        else:
            start = max(now, free)                     # pipelined behind it
        fault = (self.link_fault_fn(mig.src_id, start, mig.dt)
                 if self.link_fault_fn is not None else None)
        if fault is not None and fault[0] == "fail":
            # link drops the transfer partway: the slot is wasted up to the
            # detection point, then the retry path decides what happens
            t_fail = max(fault[1], start)
            self._link_free[mig.src_id] = t_fail
            self.loop.push(t_fail, self._handle, "migrate_fail", mig)
            return
        if fault is not None and fault[0] == "stall":
            # link wedged: the transfer (and the burst behind it) waits out
            # the stall, then completes — no KV loss, just delay
            start = max(fault[1], start)
            self.stall_trace.append((now, mig.req.rid, mig.src_id, start))
        done = max(start, now) + mig.dt
        self._link_free[mig.src_id] = done
        self.loop.push(done, self._handle, "migrate_arrive", mig)

    def _on_migrate_fail(self, mig: _Migration) -> None:
        """A transfer the link dropped: retry with capped exponential
        backoff while the per-request deadline still admits another full
        attempt; otherwise write the KV off and requeue from scratch —
        exactly the failure path, so nothing new can go wrong here."""
        now = self.loop.now
        mig.attempt += 1
        delay = min(self.cfg.migrate_backoff_s * (2.0 ** (mig.attempt - 1)),
                    self.cfg.migrate_backoff_cap_s)
        if (mig.attempt <= self.cfg.migrate_max_retries
                and now + delay + mig.dt <= mig.deadline):
            self.retry_trace.append(
                (now, mig.req.rid, mig.src_id, mig.attempt))
            self.loop.push(now + delay, self._handle, "migrate_retry", mig)
            return
        # give up: KV single-residency means the bytes in flight were the
        # only copy — the request re-enters through the router from scratch
        self._outbound[mig.src_id] -= 1
        why = ("retries" if mig.attempt > self.cfg.migrate_max_retries
               else "deadline")
        self.kv_loss_trace.append((now, mig.req.rid, mig.src_id, why))
        mig.req.reset_for_requeue()
        self.requeue_trace.append((now, mig.req.rid, mig.src_id))
        self.loop.push(now + self.cfg.requeue_latency_s,
                       self._handle, "requeue", mig.req)
        if self.cs.nodes[mig.src_id].leaving:
            self._on_leave_check(mig.src_id)

    def _on_migrate_arrive(self, mig: _Migration):
        self._outbound[mig.src_id] -= 1
        self._try_adopt(mig.req)
        src = self.cs.nodes[mig.src_id]
        if src.leaving:
            self._on_leave_check(mig.src_id)

    def _try_adopt(self, req: SimRequest):
        """Resume a migrated request on a node with decode slack, most
        slack first — the node-level estimate can disagree with
        ``adopt_decode``'s per-GPU batch check, so fall through the
        candidates before conceding. Only when every pool is saturated,
        retry later: backpressure, like the ring."""
        cands = []
        for nd in self.cs.active_nodes():
            if nd.leaving or nd.defunct:
                continue
            dgpus = nd.decode_gpus()
            if not dgpus:
                continue
            cap = nd.cost.max_decode_batch(int(nd._global_avg_ctx()))
            used = sum(len(nd.gpus[g].active) + len(nd.gpus[g].pending_join)
                       for g in dgpus)
            slack = cap * len(dgpus) - used
            if slack > 0:
                cands.append((slack, nd))
        cands.sort(key=lambda c: (-c[0], c[1].node_id))
        for _, nd in cands:
            if nd.adopt_decode(req):
                return
        self.loop.push(self.loop.now + self.cfg.adopt_retry_s,
                       self._handle, "adopt_retry", req)

    def _on_requeue(self, req: SimRequest):
        now = self.loop.now
        live = [nd for nd in self.cs.active_nodes()
                if not nd.leaving and not nd.defunct]
        if not live:
            self.loop.push(now + self.cfg.requeue_latency_s,
                           self._handle, "requeue", req)
            return
        # re-entry goes through SLO-aware admission: a requeue storm into
        # an emergency-shrunk fleet must shed, not queue into violation
        # (local admission while the controller is down, like arrivals)
        decide = (self.cs.router.decide_local if self.cs.controller_down
                  else self.cs.router.decide)
        verdict, node = decide(now, live, req)
        if verdict == "shed":
            self.cs.mark_shed(req)
        elif verdict == "defer":
            self.loop.push(now + self.cs.router.adm.defer_s,
                           self._handle, "requeue", req)
        else:
            assert node is not None
            node.submit(req)

    # ---------------- leave (graceful drain) ----------------
    def _on_leave(self, nid: int):
        if not self.cs.active[nid]:
            return
        now = self.loop.now
        node = self.cs.nodes[nid]
        self.cs.active[nid] = False          # router stops immediately
        if self.cs._flip_node == nid:        # coordinator drain dies with it
            self.cs._flip_node = None
        self.churn_trace.append((now, "leave", nid))
        if not self.cfg.elastic:
            # static fleet has no migration path: the maintenance pull
            # loses in-flight work (requeued from scratch) and nobody
            # re-levels the watts it strands
            self._fail_node(nid, redistribute=False)
            return
        node.leaving = True
        no_kv, with_kv = node.evict_for_leave()
        self._migrate_out(no_kv, node, False, "leave")
        self._migrate_out(with_kv, node, True, "leave")
        self._force_tokens[nid] = self.loop.push(
            now + self.cfg.drain_grace_s, self._handle, "leave_force", nid)
        self._on_leave_check(nid)

    def _on_leave_check(self, nid: int):
        node = self.cs.nodes[nid]
        if not node.leaving:
            return
        if node.is_empty() and self._outbound.get(nid, 0) == 0:
            self._finish_leave(node)

    def _on_leave_force(self, nid: int):
        """Drain deadline hit: maintenance windows don't wait. Whatever is
        still on the node is failed out (requeue from scratch)."""
        node = self.cs.nodes[nid]
        if not node.leaving:
            return
        self.churn_trace.append((self.loop.now, "leave_forced", nid))
        node.leaving = False
        self._fail_node(nid, redistribute=self.cfg.redistribute)

    def _finish_leave(self, node: NodeSimulator):
        now = self.loop.now
        nid = node.node_id
        node.leaving = False
        node.defunct = True              # straggler events die quietly
        token = self._force_tokens.pop(nid, None)
        if token is not None:
            self.loop.cancel(token)
        released = node.pm.power_off(now)
        node.power_samples.append((now, 0.0))
        if node.prefix_cache is not None:
            node.prefix_cache.clear()     # cached KV powers off with it
        self.cs.router.invalidate_affinity(nid)
        self.churn_trace.append((now, "leave_done", nid))
        if self.cfg.redistribute and released > 0:
            self._grow_survivors(released)
        self.cs.assert_facility_invariant()

    # ---------------- failure (abrupt) ----------------
    def _on_fail(self, nid: int):
        if not self.cs.active[nid]:
            return
        self.cs.active[nid] = False
        self.churn_trace.append((self.loop.now, "fail", nid))
        if self.cs._flip_node == nid:
            self.cs._flip_node = None
        self.cs.nodes[nid].leaving = False
        token = self._force_tokens.pop(nid, None)
        if token is not None:
            self.loop.cancel(token)
        self._fail_node(
            nid, redistribute=self.cfg.elastic and self.cfg.redistribute)

    def _on_fail_group(self, node_ids: Sequence[int]):
        """Correlated failure: k co-located nodes die in one instant. The
        eviction/power-off work runs per node, but the facility re-levels
        ONCE with the pooled watts — each survivor sees a single budget
        grow, not k sequential ones."""
        now = self.loop.now
        released = 0.0
        any_down = False
        for nid in node_ids:
            if not self.cs.active[nid]:
                continue
            any_down = True
            self.cs.active[nid] = False
            self.churn_trace.append((now, "fail", nid))
            if self.cs._flip_node == nid:
                self.cs._flip_node = None
            self.cs.nodes[nid].leaving = False
            token = self._force_tokens.pop(nid, None)
            if token is not None:
                self.loop.cancel(token)
            released = released + self._fail_node_core(nid)
        if not any_down:
            return
        if self.cfg.elastic and self.cfg.redistribute and released > 0:
            self._grow_survivors(released)
        self.cs.assert_facility_invariant()

    def _fail_node(self, nid: int, redistribute: bool):
        released = self._fail_node_core(nid)
        if redistribute and released > 0:
            self._grow_survivors(released)
        self.cs.assert_facility_invariant()

    def _fail_node_core(self, nid: int) -> float:
        """Evict, requeue, and power off one failed node; returns the watts
        it released WITHOUT redistributing them (the caller pools them —
        correlated failures re-level once for the whole group)."""
        now = self.loop.now
        node = self.cs.nodes[nid]
        reqs = node.evict_for_failure()      # marks the node defunct
        released = node.pm.power_off(now)
        node.power_samples.append((now, 0.0))
        # the prefix cache died with the HBM (evict_for_failure cleared
        # it); stale router hints must stop steering sessions here
        self.cs.router.invalidate_affinity(nid)
        for req in reqs:
            node.release_record(req)
            # KV and generated tokens are gone; the spent joules are not
            req.reset_for_requeue()
            self.requeue_trace.append((now, req.rid, nid))
            self.loop.push(now + self.cfg.requeue_latency_s,
                           self._handle, "requeue", req)
        return released

    # ---------------- non-oracle death + failure detection ----------------
    def _on_die(self, nid: int) -> None:
        """Physical death, unobserved: the node's state is destroyed NOW
        (KV loss, power dark — that is physics) but the control plane
        learns nothing here. The evicted requests and released watts go to
        limbo; the failure detector's dead verdict (``declare_dead``)
        requeues and re-levels them later — the detection latency is real
        lost time, which is exactly what the oracle fail path hid."""
        now = self.loop.now
        node = self.cs.nodes[nid]
        if node.defunct or not node.pm.powered:
            return
        self.cs.active[nid] = False
        self._suspected.discard(nid)
        if self.cs._flip_node == nid:
            self.cs._flip_node = None
        node.leaving = False
        token = self._force_tokens.pop(nid, None)
        if token is not None:
            self.loop.cancel(token)
        self.churn_trace.append((now, "die", nid))
        reqs = node.evict_for_failure()      # marks the node defunct
        released = node.pm.power_off(now)
        node.power_samples.append((now, 0.0))
        self.cs.router.invalidate_affinity(nid)
        for req in reqs:
            node.release_record(req)
            req.reset_for_requeue()
        self._limbo[nid] = reqs
        self._limbo_watts[nid] = released

    def suspect(self, nid: int) -> None:
        """Failure-detector suspicion: de-route the node, nothing more. Its
        queues, batches, and KV keep running — suspicion must be cheap to
        undo, because heartbeat loss is often the telemetry path, not the
        node."""
        node = self.cs.nodes[nid]
        if node.defunct or node.leaving or not self.cs.active[nid]:
            return
        self.cs.active[nid] = False
        self._suspected.add(nid)
        self.churn_trace.append((self.loop.now, "suspected", nid))

    def reintegrate(self, nid: int) -> None:
        """A suspected node heartbeated again (false suspicion): route to
        it again. Nothing was evicted, so nothing is lost — the
        reintegration-without-KV-loss path."""
        if nid not in self._suspected:
            return
        self._suspected.discard(nid)
        node = self.cs.nodes[nid]
        if node.defunct or not node.pm.powered:
            return
        self.cs.active[nid] = True
        self.churn_trace.append((self.loop.now, "reintegrated", nid))

    def declare_dead(self, nid: int) -> None:
        """Failure-detector dead verdict — the moment the control plane
        KNOWS. For a physically-dead node (limbo) this releases the
        stranded work and watts into the normal recovery paths; for a node
        that is actually alive but unheard past the dead timeout, fence it
        out like a failure (split-brain guard: a node the control plane
        declared dead must not keep serving)."""
        now = self.loop.now
        self._suspected.discard(nid)
        node = self.cs.nodes[nid]
        if nid in self._limbo:
            reqs = self._limbo.pop(nid)
            watts = self._limbo_watts.pop(nid, 0.0)
            self.churn_trace.append((now, "dead_detected", nid))
            for req in reqs:
                self.requeue_trace.append((now, req.rid, nid))
                self.loop.push(now + self.cfg.requeue_latency_s,
                               self._handle, "requeue", req)
            if self.cfg.elastic and self.cfg.redistribute and watts > 0:
                self._grow_survivors(watts)
            self.cs.assert_facility_invariant()
            return
        if node.defunct or not node.pm.powered:
            return      # already handled (oracle fail / graceful leave)
        self.cs.active[nid] = False
        self.churn_trace.append((now, "fenced", nid))
        if self.cs._flip_node == nid:
            self.cs._flip_node = None
        node.leaving = False
        token = self._force_tokens.pop(nid, None)
        if token is not None:
            self.loop.cancel(token)
        self._fail_node(
            nid, redistribute=self.cfg.elastic and self.cfg.redistribute)

    # ---------------- controller crash / restart ----------------
    def _on_ctrl_crash(self, duration_s: float) -> None:
        """Coordinator + autoscaler die for a window. Nodes run headless:
        each locally enforces its last-committed caps (the PowerManager
        state is node-local and survives), admission degrades to local
        SLO-aware shedding, and any budget grant maturing in the window is
        epoch-fenced. Overlapping windows coalesce into the first."""
        now = self.loop.now
        if self.cs.controller_down:
            return
        self.cs.controller_down = True
        self.cs.crash_trace.append((now, "crash", self.cs.controller_epoch))
        self.loop.push(now + duration_s, self._handle, "ctrl_restart", None)

    def _on_ctrl_restart(self, _payload: object) -> None:
        """Controller restart: bump the epoch (fencing every grant the
        dead incarnation issued), rebuild coordinator state from its
        periodic checkpoint, announce the restart so the autoscaler
        replays its journal, and re-level facility headroom the fenced
        grants left unclaimed (raise-only, self-clamping)."""
        now = self.loop.now
        if not self.cs.controller_down:
            return
        self.cs.controller_down = False
        self.cs.controller_epoch += 1
        self.cs.restore_control()
        self.cs.crash_trace.append(
            (now, "restart", self.cs.controller_epoch))
        self.loop.publish("controller_restart", self.cs.controller_epoch)
        if self.cfg.elastic and self.cfg.redistribute:
            self._grow_survivors(self.cs.facility_budget_w)
        self.cs.assert_facility_invariant()

    # ---------------- join ----------------
    def _on_join(self, nid: int):
        if self.cs.active[nid]:
            self.pending_joins.discard(nid)
            return
        now = self.loop.now
        node = self.cs.nodes[nid]
        self.pending_joins.add(nid)
        self.churn_trace.append((now, "join", nid))
        if not (self.cfg.elastic and self.cfg.redistribute):
            # static arm: the node reclaims its stranded nameplate watts —
            # nothing was re-leveled while it was away (clamped against the
            # facility's *effective* limit: emergencies bind everyone)
            headroom = self.cs.facility_limit_w - \
                sum(nd.pm.budget for nd in self.cs.nodes)
            grant = min(headroom, self._nameplate[nid])
            self._activate(node, grant)
            return
        # elastic join: facility-level DISTRIBUTEUNIFORMPOWER, source-
        # before-sink one level up — survivors shrink toward the uniform
        # share of the new membership first; the joiner powers on only when
        # those shrinks are in force and their watts committed. The share
        # is computed against the effective limit, not the nameplate: a
        # join landing mid-emergency must fit the slashed budget.
        live = [nd for nd in self.cs.active_nodes() if nd.pm.powered]
        uniform = self.cs.facility_limit_w / (len(live) + 1)
        t_ready, shrunk = now, []
        for nd in live:
            target = max(min(uniform, nd.pm.budget_ceil_w),
                         nd.pm.budget_floor_w)
            if (nd.pm.budget > target + 1.0
                    and not nd.pm.budget_op_inflight
                    and nd.node_id not in self.cs._inflight):
                tr, freed = nd.pm.shrink_budget(now, nd.pm.budget - target)
                if freed > 0:
                    shrunk.append(nd.node_id)
                    t_ready = max(t_ready, tr)
        self.cs.churn_inflight = True        # coordinator pauses budget ops
        self.loop.push(t_ready, self._handle, "join_commit", (nid, shrunk))

    def _on_join_commit(self, nid: int, shrunk: List[int]):
        now = self.loop.now
        for sid in shrunk:
            if self.cs.nodes[sid].pm.powered:
                self.cs.nodes[sid].pm.commit_budget(now)
        self.cs.churn_inflight = False
        node = self.cs.nodes[nid]
        # whatever the facility holds free NOW is what the joiner may take —
        # recomputed from live budgets so concurrent churn cannot overdraw,
        # and against the *effective* limit so a join commit landing while
        # an emergency slashed the facility budget clamps its grant (or
        # defers entirely) instead of powering on at a stale share
        avail = self.cs.facility_limit_w - \
            sum(nd.pm.budget for nd in self.cs.nodes)
        grant = min(avail, node.pm.budget_ceil_w)
        if grant < node.pm.budget_floor_w - 1e-9:
            # facility too tight right now (e.g. a concurrent failure ate
            # the headroom): retry the join shortly
            self.churn_trace.append((now, "join_deferred", nid))
            self.loop.push(now + 1.0, self._handle, "join", nid)
            return
        self._activate(node, grant)
        leftover = avail - grant
        if leftover > 1e-9:
            self._grow_survivors(leftover)

    def _activate(self, node: NodeSimulator, grant: float):
        now = self.loop.now
        nid = node.node_id
        node.defunct = False
        node.leaving = False
        for gpu in node.gpus:
            # pre-departure execution state is moot: drains are cancelled
            # and a plan truncated at leave time lost its completion event
            # with the defunct node, so the iterating latch must not stick
            gpu.draining = False
            gpu.busy = False
            gpu.iterating = False
            gpu.plan = None
            gpu.gen += 1
            gpu.inflight_prefill = None
        node._next_due = float("inf")
        node._ext_flip_gids.clear()
        node._role_version += 1
        if node.prefix_cache is not None:
            # rejoin powers fresh HBM: nothing cached survives the window,
            # and no router hint may claim otherwise
            node.prefix_cache.clear()
        self.cs.router.invalidate_affinity(nid)
        absorbed = node.pm.power_on(now, grant)
        self.cs.active[nid] = True
        self.pending_joins.discard(nid)
        node.start()                     # ctrl/sampling tick resumes
        self.churn_trace.append((now, "join_done", nid))
        self.cs.assert_facility_invariant()
        return absorbed

    # ---------------- facility power emergency ----------------
    def _on_emergency_begin(self, frac: float):
        """Demand-response cap slash: the facility's effective limit drops
        to ``frac`` of nameplate. Every powered node force-throttles toward
        the uniform share of the new limit, source-before-sink: caps are
        cut first (``PowerManager.emergency_shrink``, preemptive — it may
        retarget an op already in flight, tighter wins), and the watts
        release at ``emergency_commit`` once the lowered caps are in
        force. The coordinator holds its power plan for the whole window
        (``ClusterSimulator.emergency_hold``): shifting watts around mid-
        emergency is how real incidents become outages."""
        now = self.loop.now
        self._emergency_fracs.append(frac)
        limit = self.cs.facility_budget_w * min(self._emergency_fracs)
        self.emergency_active = True
        self._emergency_enforced = False
        self._emergency_gen += 1
        self.cs.emergency_hold = True
        self.cs.facility_limit_w = limit
        self.emergency_trace.append((now, "begin", limit))
        powered = [nd for nd in self.cs.nodes if nd.pm.powered]
        uniform = limit / max(len(powered), 1)
        t_ready, shrunk = now, []
        for nd in powered:
            tr, _ = nd.pm.emergency_shrink(now, uniform)
            t_ready = max(t_ready, tr)
            if nd.pm.budget_op_inflight:
                # ours or a coordinator shift we just retargeted: either
                # way the commit must wait for every pending lower
                shrunk.append(nd.node_id)
                for ch in nd.pm.pending:
                    t_ready = max(t_ready, ch.effective_at)
        self.loop.push(t_ready, self._handle, "emergency_commit",
                       (self._emergency_gen, tuple(shrunk)))

    def _on_emergency_commit(self, gen: int, shrunk: Sequence[int]):
        """Sink side of the emergency shrink: the lowered caps are now in
        force, so the promised budgets become real. A commit superseded by
        a newer (tighter) window is stale — the newer commit lists every
        node still mid-op, so nothing is stranded."""
        now = self.loop.now
        if gen != self._emergency_gen:
            return
        for sid in shrunk:
            pm = self.cs.nodes[sid].pm
            if (pm.powered and pm.budget_op_inflight
                    and sid not in self.cs._inflight):
                # coordinator shifts commit on their own budget_ready path
                # (their sink grant is clamped against the limit there)
                pm.commit_budget(now)
        if self.emergency_active:
            self._emergency_enforced = True
            self.emergency_trace.append(
                (now, "enforced", self.cs.facility_limit_w))
            self.cs.assert_facility_invariant()
        else:
            # the window closed before the shrinks landed (sub-enforce-
            # latency emergency): restore what we just took
            self._grow_survivors(self.cs.facility_budget_w)

    def _on_emergency_end(self, frac: float):
        now = self.loop.now
        if frac in self._emergency_fracs:
            self._emergency_fracs.remove(frac)
        if self._emergency_fracs:
            # an overlapping window is still open; relax to the tightest
            # survivor (raise-only: growing toward a looser limit is safe)
            limit = self.cs.facility_budget_w * min(self._emergency_fracs)
            if limit > self.cs.facility_limit_w + 1e-9:
                self.cs.facility_limit_w = limit
                self.emergency_trace.append((now, "relax", limit))
                self._grow_survivors(self.cs.facility_budget_w)
            return
        self.emergency_active = False
        self._emergency_enforced = False
        self.cs.emergency_hold = False
        self.cs.facility_limit_w = self.cs.facility_budget_w
        self.emergency_trace.append((now, "end", self.cs.facility_limit_w))
        # freed headroom re-levels across the survivors (raise-only); if
        # the shrink commit is still in flight it finishes the restore
        self._grow_survivors(self.cs.facility_budget_w)
        self.cs.assert_facility_invariant()

    # ---------------- facility re-leveling (raise-only side) -------------
    def _grow_survivors(self, watts: float) -> float:
        """Distribute freed watts across the active membership toward the
        facility-uniform share: least-headroom first, so a node clamping at
        its GPU-cap ceiling rolls its share onward. Raise-only — freed
        watts cannot violate the facility cap — so it applies immediately,
        exactly like ``PowerManager.grow_budget`` one level down. Watts no
        eligible node can absorb right now (mid-budget-op, at ceiling, or
        the membership momentarily empty) are re-offered shortly instead of
        stranding — a later join/commit can still take them."""
        now = self.loop.now
        live = [nd for nd in self.cs.active_nodes()
                if nd.pm.powered and not nd.pm.budget_op_inflight]
        # a deferred re-offer may race a join that already granted (part
        # of) these watts: the live budgets are authoritative, so clamp the
        # claim to what the facility actually still holds free — under the
        # *effective* limit, so a regrow mid-emergency cannot undo the slash
        headroom = self.cs.facility_limit_w - \
            sum(nd.pm.budget for nd in self.cs.nodes)
        left = watts = min(watts, max(headroom, 0.0))
        if watts <= 1e-6:
            return 0.0
        if live:
            order = sorted(live,
                           key=lambda nd: nd.pm.budget_ceil_w - nd.pm.budget)
            for i, nd in enumerate(order):
                share = left / (len(order) - i)
                give = min(share, nd.pm.budget_ceil_w - nd.pm.budget)
                if give > 1e-9:
                    left -= nd.pm.grow_budget(now, give)
        blocked = any(nd.pm.powered and nd.pm.budget_op_inflight
                      for nd in self.cs.active_nodes())
        if left > 1e-6 and (blocked or not live):
            # only retry while something can still change hands — a fleet
            # pinned at its GPU-cap ceilings has genuinely no use for them
            self.loop.push(now + 1.0, self._handle, "regrow", left)
        return watts - left
