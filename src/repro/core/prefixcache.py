"""Per-node radix-style prefix cache: KV reuse for session traffic.

Multi-turn agentic traffic re-sends its whole conversation every turn,
and whole tenant populations share one system prompt — SGLang's radix
cache showed that serving this workload WITHOUT prefix reuse wastes most
of the prefill budget re-computing KV the node already produced. This
module models that reuse analytically, the same way the rest of
``core/`` models step times: a request whose prompt starts with a cached
prefix prefills only the un-cached suffix, shortening ``prefill_time``
and shrinking the prefill joules charged to its record.

Structure: a radix-style tree flattened into a dict keyed by the
*cumulative* path tuple — ``("sys:acme",)``, ``("sys:acme", "s0")``,
``("sys:acme", "s0", "t1")`` — one entry per segment, each holding its
segment's token count and an LRU stamp. The **prefix-closure invariant**
(every entry's parent is present) holds at all times: lookups walk the
request's path from the root and stop at the first miss, inserts create
missing levels root-first, and LRU eviction only removes *childless*
entries. Capacity is a token budget carved from the node's KV memory
(``PrefixCacheConfig.frac`` of what ``CostModel.max_decode_batch``
derives from HBM minus weights); accounting is integer tokens end to
end, so there is no float drift and macro/iter runs stay bit-identical.

Cache *contents* follow the node's physical fate: ``clear()`` on node
failure or rejoin (the KV is gone with the HBM), and a leaf may be
detached (``pop_leaf``) to travel with a live request's KV migration,
re-attaching at the destination only if its parent prefix is already
resident there (``adopt``) — partial KV without its prefix is useless.
Every entry carries a globally unique ``block_id`` (birth node, serial)
so the runtime sanitizer can assert single-residency across the fleet,
exactly as it does for requests.

Determinism: LRU stamps come from a monotone serial counter, not a
clock; eviction order is a pure function of the touch sequence, which is
identical under both simulator fidelities because lookups and inserts
happen only inside prefill events that fire identically under both.
simcheck RC007 guards the tables (``_radix``, ``_used_tokens``, ...)
against writes outside this module's public API.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

PathKey = Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class PrefixCacheConfig:
    """Knobs for building one node's cache.

    ``frac`` is the share of the node's free KV memory (HBM minus
    weights, per GPU, summed over the node) reserved for prefix reuse;
    ``carry_on_migrate`` lets a live request's own leaf travel with its
    KV migration instead of dying with the source node's cache.
    """
    frac: float = 0.05
    carry_on_migrate: bool = True


@dataclasses.dataclass(frozen=True)
class PrefixBlock:
    """A detached cache leaf in flight with a KV migration: the unit of
    cross-node prefix transfer. Zero cache residency while detached —
    it lives only on the migrating request until ``adopt`` re-attaches
    it (or KV loss drops it)."""
    block_id: Tuple[int, int]
    path: PathKey
    seg_tokens: int


class _Entry:
    """One radix segment: cumulative path -> (tokens, LRU stamp,
    child count)."""
    __slots__ = ("block_id", "seg_tokens", "last_touch", "children")

    def __init__(self, block_id: Tuple[int, int], seg_tokens: int,
                 last_touch: int):
        self.block_id = block_id
        self.seg_tokens = seg_tokens
        self.last_touch = last_touch
        self.children = 0


class PrefixCache:
    """Radix-style prefix cache for one node (see module docstring).

    State is integer-token accounting under ``capacity_tokens``; all
    mutation goes through ``lookup``/``insert``/``clear``/``pop_leaf``/
    ``adopt`` (simcheck RC007)."""

    def __init__(self, node_id: int, capacity_tokens: int):
        self.node_id = node_id
        self.capacity_tokens = int(capacity_tokens)
        self._radix: Dict[PathKey, _Entry] = {}
        self._used_tokens = 0
        self._clock = 0
        self._block_serial = 0
        # observability (plain counters, not load-bearing state)
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # ---------------- read side ----------------
    @property
    def used_tokens(self) -> int:
        """Tokens currently resident (the sanitizer cross-checks this
        against the sum over entries)."""
        return self._used_tokens

    def __len__(self) -> int:
        return len(self._radix)

    def entries(self) -> Iterator[Tuple[PathKey, "_Entry"]]:
        """Iterate (path, entry) pairs — the sanitizer's residency walk."""
        return iter(self._radix.items())

    def match_tokens(self, path: PathKey) -> int:
        """Cached token count of the deepest resident prefix of ``path``,
        WITHOUT touching LRU state (router-side estimation)."""
        total = 0
        for k in range(1, len(path) + 1):
            ent = self._radix.get(path[:k])
            if ent is None:
                break
            total += ent.seg_tokens
        return total

    # ---------------- mutation API (RC007 writers) ----------------
    def lookup(self, path: PathKey) -> int:
        """Cached token count of the deepest resident prefix of ``path``,
        touching every matched level (LRU). Called once per request at
        prefill-batch launch — the instant the reuse is physically
        realized."""
        total = 0
        matched = False
        for k in range(1, len(path) + 1):
            ent = self._radix.get(path[:k])
            if ent is None:
                break
            matched = True
            self._clock += 1
            ent.last_touch = self._clock
            total += ent.seg_tokens
        if matched:
            self.hits += 1
        else:
            self.misses += 1
        return total

    def insert(self, path: PathKey, seg_tokens: Tuple[int, ...]) -> None:
        """Make ``path`` resident: create every missing level root-first
        (``seg_tokens[i]`` is level ``i``'s segment size), touch existing
        ones, and LRU-evict childless entries to fit the token budget.
        A segment larger than the whole budget is skipped (and with it
        its would-be descendants — closure is never broken)."""
        assert len(seg_tokens) == len(path), (path, seg_tokens)
        for k in range(1, len(path) + 1):
            key = path[:k]
            ent = self._radix.get(key)
            if ent is not None:
                self._clock += 1
                ent.last_touch = self._clock
                continue
            seg = int(seg_tokens[k - 1])
            if seg > self.capacity_tokens:
                return                   # cannot ever fit: stop this branch
            self._evict_to_fit(seg, protect=path)
            if self._used_tokens + seg > self.capacity_tokens:
                return                   # only protected entries left
            self._block_serial += 1
            self._clock += 1
            self._radix[key] = _Entry((self.node_id, self._block_serial),
                                      seg, self._clock)
            self._used_tokens += seg
            if k > 1:
                self._radix[path[:k - 1]].children += 1

    def clear(self) -> None:
        """Drop everything — the node's HBM (and the KV in it) is gone.
        Called on node failure and on rejoin after a power-off."""
        self._radix = {}
        self._used_tokens = 0

    def pop_leaf(self, path: PathKey) -> Optional[PrefixBlock]:
        """Detach ``path``'s entry for a KV migration, only if resident
        and childless (an interior segment is load-bearing for other
        sessions and stays). Returns the detached block, or ``None``."""
        ent = self._radix.get(path)
        if ent is None or ent.children != 0:
            return None
        del self._radix[path]
        self._used_tokens -= ent.seg_tokens
        if len(path) > 1:
            self._radix[path[:-1]].children -= 1
        return PrefixBlock(ent.block_id, path, ent.seg_tokens)

    def adopt(self, block: PrefixBlock) -> bool:
        """Re-attach a migrated block, keeping its identity. Requires its
        parent prefix to be resident here already (a suffix without its
        prefix is unusable KV) and the token budget to fit it after LRU
        eviction; returns whether the block landed (a dropped block is
        simply lost — the next prefill recomputes it)."""
        if block.path in self._radix:
            return False
        if len(block.path) > 1 and block.path[:-1] not in self._radix:
            return False
        if block.seg_tokens > self.capacity_tokens:
            return False
        self._evict_to_fit(block.seg_tokens, protect=block.path[:-1])
        if self._used_tokens + block.seg_tokens > self.capacity_tokens:
            return False
        self._clock += 1
        self._radix[block.path] = _Entry(block.block_id, block.seg_tokens,
                                         self._clock)
        self._used_tokens += block.seg_tokens
        if len(block.path) > 1:
            self._radix[block.path[:-1]].children += 1
        return True

    def _evict_to_fit(self, incoming_tokens: int, protect: PathKey) -> None:
        """LRU-evict childless entries until ``incoming_tokens`` fits,
        never touching prefixes of ``protect`` (the path being inserted).
        Eviction order is the deterministic touch-serial order."""
        protected = {protect[:k] for k in range(1, len(protect) + 1)}
        while self._used_tokens + incoming_tokens > self.capacity_tokens:
            victim_key = None
            victim_touch = 0
            for key, ent in self._radix.items():
                if ent.children != 0 or key in protected:
                    continue
                if victim_key is None or ent.last_touch < victim_touch:
                    victim_key, victim_touch = key, ent.last_touch
            if victim_key is None:
                return
            ent = self._radix.pop(victim_key)
            self._used_tokens -= ent.seg_tokens
            if len(victim_key) > 1:
                self._radix[victim_key[:-1]].children -= 1
            self.evictions += 1
