"""Control-plane telemetry: sampled node state, heartbeat failure
detection, and durable state for crash-recoverable coordination.

Until this module existed, every controller in the repo was omniscient:
``ClusterCoordinator``, ``PowerAwareRouter``, and ``PredictiveAutoscaler``
read exact node state at the instant of every decision, and a node failure
was known fleet-wide the moment it happened. Real control planes see the
world through a telemetry pipeline that samples, lags, and sometimes lies —
and they crash. Three pieces close that gap:

``TelemetryBus``
    The one read path controllers use for node state (stress summaries,
    router load signals, prefill capacity, marginal joules). By default
    every read samples the node live — bit-identical to the direct reads it
    replaced, so the entire existing test/benchmark surface is unchanged.
    The ``ChaosEngine`` (and ONLY it — simcheck RC006) may install
    ``telemetry_fault_fn`` to degrade the pipeline per node and window:

    * ``"freeze"`` — reads serve the last-known-good snapshot; staleness
      grows for the whole window (a wedged collector).
    * ``"drop"`` — like freeze for state reads, and additionally the
      node's heartbeats are swallowed (a partitioned telemetry path): the
      failure detector may falsely suspect a healthy node.
    * ``("sample", period_s)`` — sample-and-hold: reads refresh at most
      once per period, so staleness is bounded by the period (a coarse
      but honest pipeline).

    Every node carries a freshness clock; ``staleness``/``max_staleness``
    expose how old the served view is, and controllers hold their power
    plans when the view exceeds ``TelemetryConfig.max_staleness_s``
    (unless ``act_on_stale`` — the deliberately-broken naive arm of the
    fig14 benchmark).

``HeartbeatDetector``
    Replaces the oracle "fail event = instantly known dead". Nodes publish
    ``"heartbeat"`` events from their periodic control tick (a powered-off
    or dead node simply stops); the detector drives an
    alive -> suspected -> dead state machine per node with configurable
    timeouts. A *suspected* node is only de-routed (``FleetManager.
    suspect`` — no eviction, KV intact), so a false suspicion heals by
    reintegration the moment a heartbeat gets through. A *dead* verdict
    triggers real recovery: ``FleetManager.declare_dead`` requeues the
    work a physically-dead node stranded (``schedule_die`` keeps it in
    limbo until detection — watts and requests recover only when the
    control plane *learns* of the death, not when it happens) or fences a
    live node the detector gave up on (split-brain guard).

``ControlJournal``
    The durable half of crash-recoverable coordination: an append-only
    journal of admitted arrivals plus a latest-snapshot slot, modeling the
    WAL a real controller keeps outside its own process. A restarted
    ``PredictiveAutoscaler`` rebuilds bit-identical forecaster state by
    loading the snapshot and replaying the entries recorded after it
    (proven by a golden test against an uncrashed controller fed the same
    telemetry).

Determinism: nothing here draws randomness or reads a wall clock; degraded
reads are a pure function of (node, now) via the chaos engine's pre-built
window lists, so chaos runs stay bit-identical per seed.
"""
from __future__ import annotations

import dataclasses
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional, Tuple,
                    Union)

if TYPE_CHECKING:
    from repro.core.cluster import ClusterSimulator
    from repro.core.controller import NodeStress
    from repro.core.fleet import FleetManager
    from repro.core.simulator import NodeSimulator

# verdict of ``telemetry_fault_fn`` for one (node, now) read:
# None (clean) | "freeze" | "drop" | ("sample", period_s)
TelemetryFault = Union[None, str, Tuple[str, float]]


@dataclasses.dataclass
class TelemetryConfig:
    """Knobs for ``TelemetryBus`` staleness handling."""
    # controllers hold their power plan when any consulted node view is
    # older than this (a fresh read has staleness exactly 0.0)
    max_staleness_s: float = 1.0
    # keep acting on stale views anyway (the naive arm of fig14): the hold
    # is skipped but the hold_trace still records the violation
    act_on_stale: bool = False


class TelemetryBus:
    """Sampled node-state reads for every controller on one cluster.

    Accessor-per-signal (not snapshot-per-read) so the clean path stays
    allocation-free and bit-identical to the direct node reads it
    replaced. Per node it caches the last served sample of each signal;
    degraded windows (``telemetry_fault_fn``) serve those caches instead
    of sampling, and the per-node freshness clock stops advancing — which
    is exactly what ``staleness`` reports.
    """

    def __init__(self, cluster: "ClusterSimulator",
                 cfg: Optional[TelemetryConfig] = None):
        self.cs = cluster
        self.loop = cluster.loop
        self.cfg = cfg or TelemetryConfig()
        # the ONE sanctioned degradation point (simcheck RC006): the chaos
        # engine installs a pure (node_id, now) -> TelemetryFault verdict
        self.telemetry_fault_fn: Optional[
            Callable[[int, float], TelemetryFault]] = None
        # per-node caches: last served sample of each signal
        self._parts: Dict[int, Tuple[float, int, float, float]] = {}
        self._stress: Dict[int, Tuple[float, "NodeStress"]] = {}
        self._jpt: Dict[int, Tuple[float, float]] = {}
        # per-node freshness clock: last time ANY signal sampled live
        self._t_fresh: Dict[int, float] = {}

    # ---------------- degradation plumbing ----------------
    def _fault(self, node_id: int, now: float) -> TelemetryFault:
        fn = self.telemetry_fault_fn
        return fn(node_id, now) if fn is not None else None

    @staticmethod
    def _use_cached(mode: TelemetryFault, t_cached: Optional[float],
                    now: float) -> bool:
        """Whether a degraded window serves the cached sample. First
        contact inside a window (no cache yet) samples once — the
        last-known-good snapshot IS the window-entry state."""
        if mode is None or t_cached is None:
            return False
        if isinstance(mode, tuple):
            return now - t_cached < mode[1]   # sample-and-hold period
        return True                           # "freeze" / "drop"

    def heartbeat_blocked(self, node_id: int, now: float) -> bool:
        """Whether a telemetry dropout window is swallowing this node's
        heartbeats right now (mode ``"drop"`` only — a frozen window
        stales the state channel but heartbeats still arrive)."""
        return self._fault(node_id, now) == "drop"

    # ---------------- signal reads ----------------
    def _node_parts(self, nd: "NodeSimulator") -> Tuple[int, float, float]:
        """(queued prefill tokens, prefill capacity tps, queue head age) —
        the decomposed ``router_load`` inputs, so a frozen view can still
        price the arriving request's OWN tokens against frozen queue
        state."""
        now = self.loop.now
        nid = nd.node_id
        mode = self._fault(nid, now)
        cached = self._parts.get(nid)
        if self._use_cached(mode, cached[0] if cached else None, now):
            assert cached is not None
            return cached[1], cached[2], cached[3]
        parts = (nd.queued_prefill_tokens(), nd.prefill_capacity_tps(),
                 nd.queue_head_age())
        self._parts[nid] = (now, parts[0], parts[1], parts[2])
        self._t_fresh[nid] = now
        return parts

    def router_load(self, nd: "NodeSimulator",
                    extra_tokens: int = 0) -> float:
        """``NodeSimulator.router_load`` through the bus: identical float
        arithmetic on a fresh read (bit-identity with the direct call);
        on a degraded read the queue state is last-known-good but the
        arriving request's tokens are its own."""
        q_toks, rate, head_age = self._node_parts(nd)
        if rate <= 0.0:
            return float("inf")
        return (q_toks + extra_tokens) / rate + head_age

    def prefill_capacity_tps(self, nd: "NodeSimulator") -> float:
        """Effective prefill capacity (``NodeSimulator.
        prefill_capacity_tps``) through the bus."""
        return self._node_parts(nd)[1]

    def stress(self, nd: "NodeSimulator") -> "NodeStress":
        """``NodeSimulator.stress_summary`` through the bus: the
        coordinator's per-tick fleet scan."""
        now = self.loop.now
        nid = nd.node_id
        mode = self._fault(nid, now)
        cached = self._stress.get(nid)
        if self._use_cached(mode, cached[0] if cached else None, now):
            assert cached is not None
            return cached[1]
        s = nd.stress_summary()
        self._stress[nid] = (now, s)
        self._t_fresh[nid] = now
        return s

    def marginal_jpt(self, nd: "NodeSimulator", in_tokens: int,
                     out_tokens: int) -> float:
        """``NodeSimulator.marginal_joules_per_token`` through the bus.
        A degraded read serves the price computed for the LAST request
        shape sampled — a frozen pipeline cannot re-price per request."""
        now = self.loop.now
        nid = nd.node_id
        mode = self._fault(nid, now)
        cached = self._jpt.get(nid)
        if self._use_cached(mode, cached[0] if cached else None, now):
            assert cached is not None
            return cached[1]
        jpt = nd.marginal_joules_per_token(in_tokens, out_tokens)
        self._jpt[nid] = (now, jpt)
        self._t_fresh[nid] = now
        return jpt

    # ---------------- staleness bounds ----------------
    def staleness(self, nd: "NodeSimulator") -> float:
        """Age of this node's last live sample. 0.0 exactly when the most
        recent read sampled live (or nothing was ever read)."""
        return self.loop.now - self._t_fresh.get(nd.node_id, self.loop.now)

    def max_staleness(self, nodes: List["NodeSimulator"]) -> float:
        """Oldest view age across ``nodes`` — the bound a controller
        checks AFTER reading its views and BEFORE acting on them."""
        worst = 0.0
        for nd in nodes:
            s = self.staleness(nd)
            if s > worst:
                worst = s
        return worst


@dataclasses.dataclass
class HeartbeatConfig:
    """Failure-detector timeouts. Defaults assume the node control tick
    (heartbeat source) fires every ~0.25 s: suspicion needs ~3 missed
    beats, death ~8 — suspicion is cheap to undo (de-route only), death
    is not (requeue / fencing)."""
    suspect_after_s: float = 0.75   # missed-beat age before de-routing
    dead_after_s: float = 2.0       # missed-beat age before declaring dead
    check_period_s: float = 0.25    # detector sweep period


class HeartbeatDetector:
    """Alive -> suspected -> dead failure detection from heartbeats.

    Nodes publish ``"heartbeat"`` on the shared loop from their control
    tick; this detector sweeps every ``check_period_s`` and compares each
    monitored node's last-heard age against the timeouts:

    * ``suspect_after_s`` exceeded — ``FleetManager.suspect``: the node
      is de-routed (no eviction; its queues and KV keep running). A
      heartbeat that gets through reverses it (``reintegrate``) with
      nothing lost — the false-suspicion path.
    * ``dead_after_s`` exceeded — ``FleetManager.declare_dead``: a
      physically-dead node's stranded work and watts finally recover
      (``schedule_die`` limbo), or a live-but-unheard node is fenced out
      like a failure (split-brain guard: a node the control plane has
      declared dead must not keep serving).

    Monitored set: active nodes, suspected nodes, and undetected corpses
    (``FleetManager._limbo``). Nodes the fleet *chose* to power off
    (standby, graceful leave) are not monitored — their silence is known.
    """

    def __init__(self, fleet: "FleetManager",
                 cfg: Optional[HeartbeatConfig] = None):
        self.fm = fleet
        self.cs = fleet.cs
        self.loop = fleet.loop
        self.cfg = cfg or HeartbeatConfig()
        self.bus = fleet.cs.telemetry
        self.state: Dict[int, str] = {}       # node_id -> alive|suspected|dead
        self._last_hb: Dict[int, float] = {}
        self.trace: List[tuple] = []          # (t, node_id, transition)
        self.drop_trace: List[tuple] = []     # (t, node_id) swallowed beats
        now = self.loop.now
        for nd in fleet.cs.nodes:
            if fleet.cs.active[nd.node_id] and nd.pm.powered:
                self.state[nd.node_id] = "alive"
                self._last_hb[nd.node_id] = now
        self.loop.subscribe("heartbeat", self._on_heartbeat)
        fleet.detector = self

    def start(self) -> None:
        """Arm the periodic detector sweep (call before ``cluster.run``)."""
        self.loop.push(self.loop.now, self._handle, "hb_check")

    # ---------------- heartbeat sink ----------------
    def _on_heartbeat(self, payload: object) -> None:
        nid = int(payload)  # type: ignore[call-overload]
        now = self.loop.now
        if self.bus.heartbeat_blocked(nid, now):
            self.drop_trace.append((now, nid))
            return
        self._last_hb[nid] = now
        st = self.state.get(nid)
        if st is None:
            self.state[nid] = "alive"         # joined after detector start
        elif st == "suspected":
            self.state[nid] = "alive"
            self.trace.append((now, nid, "reintegrated"))
            self.fm.reintegrate(nid)
        elif st == "dead":
            # physically rejoined through a fleet join: monitor again
            self.state[nid] = "alive"
            self.trace.append((now, nid, "rejoined"))

    # ---------------- periodic sweep ----------------
    def _monitored(self, nid: int) -> bool:
        return (self.cs.active[nid] or self.state.get(nid) == "suspected"
                or nid in self.fm._limbo)

    def _handle(self, kind: str, payload: object = None) -> None:
        """Detector sweep event: drives suspected/dead transitions. Dead
        verdicts mutate cross-node state (requeues, re-levels), so the
        sweep runs under the same sync/validate discipline as fleet
        events."""
        assert kind == "hb_check", kind
        now = self.loop.now
        self.cs.sync_all()
        for nid in sorted(self.state):
            st = self.state[nid]
            if st == "dead" or not self._monitored(nid):
                continue
            age = now - self._last_hb.get(nid, now)
            if age >= self.cfg.dead_after_s:
                self.state[nid] = "dead"
                self.trace.append((now, nid, "dead"))
                self.fm.declare_dead(nid)
            elif age >= self.cfg.suspect_after_s and st == "alive":
                self.state[nid] = "suspected"
                self.trace.append((now, nid, "suspected"))
                self.fm.suspect(nid)
        self.cs.validate_all()
        if self.loop.heap:
            self.loop.push(now + self.cfg.check_period_s, self._handle,
                           "hb_check")


class ControlJournal:
    """Durable controller inputs: an arrival journal + a snapshot slot.

    Models the write-ahead log a real controller keeps OUTSIDE its own
    process: the journal keeps recording through a controller crash
    (arrivals the dead controller never saw are still journaled), and the
    snapshot is whatever state the controller last persisted. Recovery =
    ``load_state(snapshot)`` + replay of ``entries[n:]`` — deterministic
    and bit-identical to never having crashed, because the forecaster's
    state is a pure function of the observation stream.
    """

    def __init__(self, loop: object):
        self.loop = loop
        self.entries: List[Tuple[float, int]] = []   # (t, input_tokens)
        self._snapshot: Optional[Tuple[float, int, tuple]] = None
        self.n_snapshots = 0
        loop.subscribe("arrival", self._on_arrival)  # type: ignore[attr-defined]

    def _on_arrival(self, payload: object) -> None:
        rec = payload.rec if hasattr(payload, "rec") else payload
        self.entries.append(
            (self.loop.now, rec.input_tokens))  # type: ignore[attr-defined]

    def snapshot(self, state: tuple) -> None:
        """Persist controller ``state`` against the current journal
        position (latest snapshot wins — the periodic checkpoint)."""
        self._snapshot = (
            self.loop.now, len(self.entries), state)  # type: ignore[attr-defined]
        self.n_snapshots += 1

    def latest(self) -> Optional[Tuple[float, int, tuple]]:
        """The latest persisted ``(t, journal_position, state)``, if any."""
        return self._snapshot

    def replay_from(self, n: int) -> List[Tuple[float, int]]:
        """Journal entries recorded at or after position ``n`` — what a
        recovering controller replays on top of the snapshot."""
        return self.entries[n:]
