"""Persistent ring buffer for prefill -> decode KV-cache handoff
(paper Section 3.2): fixed slot count, per-slot ready flags, pull-based
consumption. In the real system the slots live in GPU memory and are
published via HIP-IPC handles over XGMI; here each slot holds the actual
JAX KV-cache pytree (on TPU the consume step is a device-to-device copy).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, List, Optional


@dataclasses.dataclass
class RingSlot:
    ready: bool = False
    payload: Any = None          # (request, kv_cache pytree, first_token)


class KVRing:
    def __init__(self, n_slots: int = 32):
        self.slots: List[RingSlot] = [RingSlot() for _ in range(n_slots)]
        self._free: deque = deque(range(n_slots))
        self._ready: deque = deque()

    def try_put(self, payload) -> Optional[int]:
        """Publish a prefilled KV cache. None if the ring is full
        (backpressure on the prefill side)."""
        if not self._free:
            return None
        idx = self._free.popleft()
        self.slots[idx] = RingSlot(ready=True, payload=payload)
        self._ready.append(idx)
        return idx

    def try_pull(self):
        """Decode side pulls the oldest ready slot (None if empty)."""
        if not self._ready:
            return None
        idx = self._ready.popleft()
        slot = self.slots[idx]
        slot.ready = False
        payload = slot.payload
        slot.payload = None
        self._free.append(idx)
        return payload

    @property
    def n_ready(self) -> int:
        return len(self._ready)

    @property
    def n_free(self) -> int:
        return len(self._free)
