"""Real-compute disaggregated serving engine.

Unlike ``core.simulator`` (analytic step times, used for the paper's power
experiments at MI300X scale), this engine runs *actual JAX forward passes*:
prefill workers fill real KV caches, the ring buffer hands the tensors to
decode workers, decode workers run continuous batching with per-slot
positions, and the SAME RapidController/PowerManager drive power and role
decisions. Power caps scale a logical clock (hardware power knobs cannot be
actuated from CPU), so the control loop sees the same dynamics end-to-end.

This is the mechanism-fidelity complement to the simulator: it proves the
KV handoff, per-slot batching, drain-and-flip role moves, and controller
integration on real tensors (CPU-sized models; TPU-sized via pjit configs).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.controller import (ControllerConfig, Observation,
                                   RapidController)
from repro.core.goodput import RequestRecord, summarize
from repro.core.power_manager import PowerManager
from repro.core.power_model import PowerModel, mi300x
from repro.models import LM
from repro.serving.ring import KVRing


@dataclasses.dataclass
class ServeRequest:
    rec: RequestRecord
    tokens: np.ndarray               # prompt
    generated: list = dataclasses.field(default_factory=list)
    slot: int = -1                   # decode slot index


def _cache_insert(family: str, dst, src, slot: int):
    """Insert a batch-1 prefilled cache into slot ``slot`` of a batched
    decode cache. Batch dim is 1 for stacked leaves, 0 for hybrid 'rest'."""
    dst = dict(dst)
    src = dict(src)
    dst.pop("pos", None)
    src.pop("pos", None)

    def ins(path, d, s):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        bdim = 0 if (keys and keys[0] == "rest") else 1
        idx = [slice(None)] * bdim + [slot]
        return d.at[tuple(idx)].set(jnp.squeeze(s, axis=bdim))
    return jax.tree_util.tree_map_with_path(ins, dst, src)


class Worker:
    def __init__(self, wid: int, role: str):
        self.wid = wid
        self.role = role
        self.draining = False
        self.free_at = 0.0           # logical clock
        # decode state
        self.active: dict = {}       # slot -> ServeRequest
        self.cache = None
        self.pos = None              # (B,) int32 per-slot positions


class DisaggEngine:
    def __init__(self, cfg: ModelConfig, *, n_prefill: int = 1,
                 n_decode: int = 1, max_len: int = 192,
                 decode_slots: int = 8, node_budget_w: float = 4800.0,
                 ctrl_cfg: Optional[ControllerConfig] = None,
                 power: Optional[PowerModel] = None, seed: int = 0,
                 caps: Optional[List[float]] = None,
                 time_scale: float = 1.0):
        self.cfg = cfg
        self.lm = LM(cfg)
        self.params = self.lm.init(jax.random.key(seed), dtype=jnp.float32)
        self.max_len = max_len
        self.decode_slots = decode_slots
        n = n_prefill + n_decode
        self.workers = ([Worker(i, "prefill") for i in range(n_prefill)] +
                        [Worker(n_prefill + i, "decode")
                         for i in range(n_decode)])
        self.pm = PowerManager(n, node_budget_w,
                               initial_caps=caps or [node_budget_w / n] * n)
        self.power = power or mi300x()
        self.ctrl = RapidController(ctrl_cfg, self.pm) if ctrl_cfg else None
        self.ctrl_cfg = ctrl_cfg
        self.ring = KVRing(32)
        self.queue: deque = deque()
        self.records: List[RequestRecord] = []
        self.finished: List[ServeRequest] = []
        self.clock = 0.0             # logical seconds
        self.time_scale = time_scale
        self.recent_ttft: deque = deque(maxlen=64)
        self.recent_tpot: deque = deque(maxlen=64)

        # jitted steps (shared across workers; params are shared)
        def _pre(p, toks, cache):
            batch = {"tokens": toks}
            if cfg.is_encoder_decoder:   # stubbed audio frontend embeddings
                batch["enc_feats"] = jnp.zeros(
                    (toks.shape[0], cfg.encoder_seq, cfg.d_model), jnp.float32)
            return self.lm.prefill(p, batch, cache)
        self._prefill = jax.jit(_pre)
        def _dec(p, tok, cache):
            logits, cache = self.lm.decode_step(p, tok, cache)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
        self._decode = jax.jit(_dec)

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, out_tokens: int, now: float,
               ttft_slo=1.0, tpot_slo=0.04):
        rid = len(self.records)
        rec = RequestRecord(rid, now, len(prompt), out_tokens,
                            ttft_slo=ttft_slo, tpot_slo=tpot_slo)
        self.records.append(rec)
        self.queue.append(ServeRequest(rec, prompt))

    def _logical_dt(self, wall: float, role: str, wid: int) -> float:
        rel = self.power.rel(role, self.pm.effective[wid])
        return wall * self.time_scale / rel

    # ------------------------------------------------------------------
    def _do_prefill(self, w: Worker) -> bool:
        if not self.queue or self.ring.n_free == 0:
            return False
        req = self.queue.popleft()
        toks = jnp.asarray(req.tokens)[None, :]
        cache = self.lm.init_cache(1, self.max_len, dtype=jnp.float32)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, toks, cache)
        jax.block_until_ready(logits)
        dt = self._logical_dt(time.perf_counter() - t0, "prefill", w.wid)
        self.clock = max(self.clock, w.free_at) + dt
        w.free_at = self.clock
        first = int(jnp.argmax(logits[0]))
        req.rec.prefill_done = self.clock
        self.recent_ttft.append(req.rec.ttft)
        req.generated.append(first)
        assert self.ring.try_put((req, cache, first)) is not None
        return True

    def _ensure_decode_state(self, w: Worker):
        if w.cache is None:
            w.cache = dict(self.lm.init_cache(self.decode_slots, self.max_len,
                                              dtype=jnp.float32))
            w.cache.pop("pos", None)
            w.pos = jnp.zeros((self.decode_slots,), jnp.int32)

    def _admit(self, w: Worker):
        self._ensure_decode_state(w)
        while len(w.active) < self.decode_slots and self.ring.n_ready:
            req, cache, _first = self.ring.try_pull()
            slot = next(i for i in range(self.decode_slots)
                        if i not in {r.slot for r in w.active.values()})
            req.slot = slot
            w.cache = _cache_insert(self.cfg.family, w.cache, cache, slot)
            w.pos = w.pos.at[slot].set(len(req.tokens))
            w.active[slot] = req

    def _do_decode_iter(self, w: Worker) -> bool:
        self._admit(w)
        if not w.active:
            return False
        # feed each slot its last token (inactive slots feed 0)
        tok = np.zeros((self.decode_slots,), np.int32)
        for slot, req in w.active.items():
            tok[slot] = req.generated[-1]
        cache = dict(w.cache)
        cache["pos"] = w.pos
        t0 = time.perf_counter()
        nxt, cache = self._decode(self.params, jnp.asarray(tok), cache)
        jax.block_until_ready(nxt)
        dt = self._logical_dt(time.perf_counter() - t0, "decode", w.wid)
        self.clock = max(self.clock, w.free_at) + dt
        w.free_at = self.clock
        self.recent_tpot.append(dt)
        w.pos = cache.pop("pos")
        w.cache = cache
        done = []
        for slot, req in list(w.active.items()):
            req.generated.append(int(nxt[slot]))
            if len(req.generated) >= req.rec.output_tokens or \
                    int(w.pos[slot]) >= self.max_len - 1:
                req.rec.finish = self.clock
                self.finished.append(req)
                done.append(slot)
        for slot in done:
            del w.active[slot]
        return True

    # ------------------------------------------------------------------
    def _ctrl_tick(self):
        if self.ctrl is None:
            return
        self.pm.tick(self.clock)
        pre = [w.wid for w in self.workers if w.role == "prefill"
               and not w.draining]
        dec = [w.wid for w in self.workers if w.role == "decode"
               and not w.draining]
        obs = Observation(
            now=self.clock,
            ttft_p90=float(np.percentile(self.recent_ttft, 90))
            if self.recent_ttft else 0.0,
            tpot_p90=float(np.percentile(self.recent_tpot, 90))
            if self.recent_tpot else 0.0,
            q_prefill=len(self.queue), q_decode=self.ring.n_ready)
        d = self.ctrl.tick(obs, pre, dec)
        if d.kind == "power":
            src, dst = (dec, pre) if d.direction == "d2p" else (pre, dec)
            t_ready, freed = self.pm.shift(self.clock, src, dst,
                                           self.ctrl_cfg.power_step_w)
            self.pm.tick(t_ready)
            self.pm.apply_raise(t_ready, dst, freed,
                                self.ctrl_cfg.decode_cap_max_w
                                if d.direction == "p2d" else None)
        elif d.kind == "gpu":
            cands = dec if d.direction == "d2p" else pre
            if len(cands) > 1:
                w = self.workers[cands[-1]]
                if not w.active:     # drain-free flip for idle workers
                    w.role = ("prefill" if d.direction == "d2p" else "decode")
                    w.cache, w.pos, w.active = None, None, {}
                    self.clock += self.ctrl_cfg.gpu_move_drain_s
                    t_r, gpus, per = self.pm.distribute_uniform(self.clock)
                    self.pm.tick(t_r)
                    self.pm.apply_uniform(t_r, gpus, per)

    # ------------------------------------------------------------------
    def run(self, max_iters: int = 10_000):
        """Drive until all submitted requests finish."""
        it = 0
        while it < max_iters:
            it += 1
            progressed = False
            for w in self.workers:
                if w.role == "prefill":
                    progressed |= self._do_prefill(w)
                else:
                    progressed |= self._do_decode_iter(w)
            self._ctrl_tick()
            if not progressed:
                if all(r.finish is not None for r in self.records):
                    break
                self.clock += 0.01
        dur = max((r.finish or self.clock) for r in self.records) \
            if self.records else self.clock
        return summarize(self.records, dur, sum(self.pm.effective))
