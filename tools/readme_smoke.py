"""Run the README quickstart verbatim, so the front door can't rot.

Extracts every fenced ``bash`` block that is immediately preceded by a
``<!-- readme-smoke -->`` marker comment and executes each command line
exactly as written (comments and blank lines skipped). A command that
exits non-zero fails the run — if the README drifts from the code, CI's
docs lane catches it here rather than a reader's terminal.

Usage:
    python tools/readme_smoke.py [README.md]
"""
from __future__ import annotations

import pathlib
import re
import subprocess
import sys
import time

MARKER = "<!-- readme-smoke -->"
FENCE = re.compile(r"^```(\w*)\s*$")


def extract_commands(text: str) -> list[str]:
    """Command lines from marker-tagged ```bash blocks, in order."""
    commands: list[str] = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        if lines[i].strip() != MARKER:
            i += 1
            continue
        # the marker must tag the fence on the next non-blank line
        j = i + 1
        while j < len(lines) and not lines[j].strip():
            j += 1
        m = FENCE.match(lines[j]) if j < len(lines) else None
        if not m or m.group(1) not in ("bash", "sh", ""):
            raise SystemExit(
                f"{MARKER} on line {i + 1} is not followed by a bash fence")
        j += 1
        while j < len(lines) and not lines[j].startswith("```"):
            cmd = lines[j].strip()
            if cmd and not cmd.startswith("#"):
                commands.append(cmd)
            j += 1
        i = j + 1
    return commands


def main(argv: list[str]) -> int:
    readme = pathlib.Path(argv[1] if len(argv) > 1 else "README.md")
    commands = extract_commands(readme.read_text())
    if not commands:
        print(f"error: no {MARKER} bash blocks found in {readme}",
              file=sys.stderr)
        return 2
    print(f"{readme}: {len(commands)} quickstart command(s)")
    for cmd in commands:
        print(f"\n$ {cmd}", flush=True)
        t0 = time.perf_counter()
        proc = subprocess.run(["bash", "-c", cmd])
        dt = time.perf_counter() - t0
        if proc.returncode != 0:
            print(f"FAILED (exit {proc.returncode}): {cmd}", file=sys.stderr)
            return 1
        print(f"ok ({dt:.1f}s)")
    print(f"\nall {len(commands)} README commands passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
