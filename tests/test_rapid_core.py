"""RAPID core behaviour: power model calibration, Algorithm 1 decisions,
power-manager source-before-sink semantics (paper Figs 4, Algorithm 1)."""
import dataclasses

import pytest

from repro.configs import get_config
from repro.core.controller import (ControllerConfig, Observation,
                                   RapidController, policy_nonuniform)
from repro.core.costmodel import MI300X, CostModel
from repro.core.power_manager import PowerManager, SimulatedSMI
from repro.core.power_model import mi300x


# -- power model calibration (paper Fig 4) ----------------------------------

def test_prefill_speedup_matches_paper():
    pm = mi300x()
    s = pm.speedup("prefill", 750) / pm.speedup("prefill", 400)
    assert 1.7 <= s <= 1.9          # paper: ~1.8x for 1.87x power


def test_decode_flattens_beyond_600w():
    pm = mi300x()
    s750 = pm.speedup("decode", 750) / pm.speedup("decode", 400)
    s600 = pm.speedup("decode", 600) / pm.speedup("decode", 400)
    assert 1.25 <= s750 <= 1.5      # paper: 1.3-1.5x
    assert (s750 - s600) / s600 < 0.05   # <5% gain beyond 600 W


def test_prefill_more_power_sensitive_than_decode():
    cm = CostModel(get_config("llama31_8b"), MI300X, mi300x())
    pre_gain = cm.prefill_time(4096, 400) / cm.prefill_time(4096, 750)
    dec_gain = cm.decode_step_time(32, 4096, 400) / \
        cm.decode_step_time(32, 4096, 750)
    assert pre_gain > dec_gain


# -- power manager ------------------------------------------------------------

def test_source_lowered_before_sink_raised():
    pm = PowerManager(8, 4800.0, backend=SimulatedSMI(0.3),
                      initial_caps=[600.0] * 8)
    t_ready, freed = pm.shift(0.0, src=[4, 5, 6, 7], dst=[0, 1, 2, 3],
                              watts_per_gpu=150.0)
    assert t_ready == pytest.approx(0.3)
    assert freed == pytest.approx(600.0)
    # before enforcement: sinks unchanged; worst case still within budget
    pm.tick(0.1)
    assert pm.effective[:4] == [600.0] * 4
    assert pm._worst_case() <= 4800.0 + 1e-6
    pm.tick(0.3)
    pm.apply_raise(0.3, [0, 1, 2, 3], freed)
    assert pm.effective[:4] == [750.0] * 4
    assert pm.effective[4:] == [450.0] * 4
    assert sum(pm.effective) <= 4800.0 + 1e-6


def test_raise_clamped_to_headroom():
    pm = PowerManager(8, 4800.0, initial_caps=[600.0] * 8)
    # raising without freeing must be clamped, not violate the budget
    pm.set_cap(0.0, 0, 750.0)
    assert pm._worst_case() <= 4800.0 + 1e-6
    assert pm.commanded[0] == pytest.approx(600.0)  # no headroom -> no-op


# -- Algorithm 1 decision table ----------------------------------------------

def _ctrl(caps=None, **kw):
    cfg = dataclasses.replace(ControllerConfig(), allow_power=True,
                              allow_gpu=True, **kw)
    pm = PowerManager(8, 4800.0, initial_caps=caps or [600.0] * 8)
    return RapidController(cfg, pm), pm


def test_ttft_stress_moves_power_decode_to_prefill():
    ctrl, _ = _ctrl()
    obs = Observation(now=100.0, ttft_p90=2.0, tpot_p90=0.02,
                      q_prefill=10, q_decode=0)
    d = ctrl.tick(obs, [0, 1, 2, 3], [4, 5, 6, 7])
    assert d.kind == "power" and d.direction == "d2p"


def test_tpot_stress_moves_power_prefill_to_decode():
    # decode below its 600 W ceiling -> power moves first
    ctrl, _ = _ctrl(caps=[650.0] * 4 + [550.0] * 4)
    obs = Observation(now=100.0, ttft_p90=0.2, tpot_p90=0.08,
                      q_prefill=0, q_decode=5)
    d = ctrl.tick(obs, [0, 1, 2, 3], [4, 5, 6, 7])
    assert d.kind == "power" and d.direction == "p2d"


def test_tpot_stress_at_decode_ceiling_moves_gpu():
    # decode already at the 600 W ceiling -> POWERLIMITSREACHED -> MoveGPU
    ctrl, _ = _ctrl()
    obs = Observation(now=100.0, ttft_p90=0.2, tpot_p90=0.08,
                      q_prefill=0, q_decode=5)
    d = ctrl.tick(obs, [0, 1, 2, 3], [4, 5, 6, 7])
    assert d.kind == "gpu" and d.direction == "p2d"


def test_gpu_move_when_power_limits_reached():
    ctrl, pm = _ctrl()
    for g in [0, 1, 2, 3]:
        pm.set_cap(0.0, g, 400.0)   # decode gpus 4..7? prefill at min
    pm.tick(1.0)
    # prefill (src for p2d) at min -> power saturated -> MoveGPU
    obs = Observation(now=100.0, ttft_p90=0.2, tpot_p90=0.08,
                      q_prefill=0, q_decode=5)
    d = ctrl.tick(obs, [0, 1, 2, 3], [4, 5, 6, 7])
    assert d.kind == "gpu" and d.direction == "p2d"


def test_both_violated_does_nothing():
    ctrl, _ = _ctrl()
    obs = Observation(now=100.0, ttft_p90=5.0, tpot_p90=0.5,
                      q_prefill=50, q_decode=50)
    d = ctrl.tick(obs, [0, 1, 2, 3], [4, 5, 6, 7])
    assert d.kind == "none"


def test_cooldown_blocks_consecutive_moves():
    ctrl, _ = _ctrl()
    obs = Observation(now=100.0, ttft_p90=2.0, tpot_p90=0.02,
                      q_prefill=10, q_decode=0)
    d1 = ctrl.tick(obs, [0, 1, 2, 3], [4, 5, 6, 7])
    assert d1.kind == "power"
    obs2 = dataclasses.replace(obs, now=100.1)
    d2 = ctrl.tick(obs2, [0, 1, 2, 3], [4, 5, 6, 7])
    assert d2.kind == "none" and d2.note == "cooldown"


def test_decode_power_capped_at_600():
    ctrl, pm = _ctrl(caps=[650.0] * 4 + [550.0] * 4)
    assert pm.at_limits(src=[0, 1, 2, 3], dst=[4, 5, 6, 7],
                        dst_max=600.0) is False
    t_ready, freed = pm.shift(0.0, [0, 1, 2, 3], [4, 5, 6, 7], 50.0)
    pm.tick(t_ready)
    pm.apply_raise(t_ready, [4, 5, 6, 7], freed, dst_max=600.0)
    assert all(c == 600.0 for c in pm.commanded[4:])
    assert pm.at_limits(src=[0, 1, 2, 3], dst=[4, 5, 6, 7],
                        dst_max=600.0) is True


def test_static_policy_labels():
    assert policy_nonuniform(750, 450).label() == "4P-750W/4D-450W"
