"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
interpret=True (the kernel body runs in Python on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref

KEY = jax.random.key(0)


@pytest.mark.parametrize("B,S,Hq,K,hd,window", [
    (2, 256, 4, 2, 64, None),
    (1, 128, 2, 2, 128, None),
    (2, 256, 4, 4, 64, 64),
    (1, 512, 8, 2, 64, None),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, Hq, K, hd, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    out = flash_attention(q, k, v, window=window)
    kk = jnp.repeat(k, Hq // K, 2)
    vv = jnp.repeat(v, Hq // K, 2)
    ref = flash_attention_ref(q.astype(jnp.float32), kk.astype(jnp.float32),
                              vv.astype(jnp.float32), window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref))) < tol


@pytest.mark.parametrize("B,S,Hq,K,hd,bs,pos", [
    (2, 1024, 8, 2, 64, 256, 700),
    (1, 512, 4, 4, 128, 128, 511),
    (3, 512, 16, 2, 64, 512, 100),
    (2, 256, 8, 8, 64, 64, 0),
])
def test_decode_attention(B, S, Hq, K, hd, bs, pos):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    out = decode_attention(q, kc, vc, pos, bs=bs)
    ref = decode_attention_ref(q, kc, vc, pos)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


@pytest.mark.parametrize("B,S,W,chunk,bw", [
    (2, 512, 256, 128, 128),
    (1, 256, 128, 256, 64),
    (3, 1024, 384, 64, 128),
    (2, 128, 256, 32, 256),
])
def test_rglru_scan(B, S, W, chunk, bw):
    ks = jax.random.split(KEY, 3)
    la = -jnp.abs(jax.random.normal(ks[0], (B, S, W))) * 0.2
    x = jax.random.normal(ks[1], (B, S, W))
    h0 = jax.random.normal(ks[2], (B, W))
    out = rglru_scan(la, x, h0, chunk=chunk, bw=bw)
    ref = rglru_scan_ref(la, x, h0)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


@pytest.mark.parametrize("B,S,hd,chunk", [
    (2, 256, 64, 128), (1, 128, 32, 32), (3, 256, 128, 256),
])
def test_mlstm_chunk(B, S, hd, chunk):
    from repro.kernels.mlstm_chunk.ops import mlstm_chunk
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, hd))
    k = jax.random.normal(ks[1], (B, S, hd)) / jnp.sqrt(hd)
    v = jax.random.normal(ks[2], (B, S, hd))
    li = jax.random.normal(ks[3], (B, S)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S)) + 3.0)
    out = mlstm_chunk(q, k, v, li, lf, chunk=chunk)
    ref = mlstm_chunk(q, k, v, li, lf, impl="ref")
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-4
