"""Cluster layer: power-aware routing, hierarchical (facility -> node ->
GPU) budget invariants incl. worst-case accounting during in-flight shifts,
and end-to-end multi-node behaviour."""
import dataclasses

import pytest

from repro.configs import get_config
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import ControllerConfig, policy_4p4d
from repro.core.goodput import RequestRecord
from repro.core.power_manager import PowerManager
from repro.core.simulator import SimRequest, Workload

CFG = get_config("llama31_8b")


def dyn(**kw):
    return dataclasses.replace(ControllerConfig(), allow_power=True,
                               allow_gpu=False, **kw)


def make_cluster(n_nodes=2, budget=4000.0, ctrl=None, shift=True, **kw):
    return ClusterSimulator(CFG, policy_4p4d(500), n_nodes,
                            node_budget_w=budget, ctrl_cfg=ctrl,
                            cluster_cfg=ClusterConfig(allow_shift=shift),
                            **kw)


# ---------------------------------------------------------------------------
# router dispatch
# ---------------------------------------------------------------------------

def test_router_prefers_less_loaded_node():
    cs = make_cluster()
    # pile queued prefill work onto node 0 only
    for i in range(6):
        cs.nodes[0].submit(SimRequest(RequestRecord(100 + i, 0.0, 8192, 16)))
    assert cs.nodes[0].router_load() > cs.nodes[1].router_load()
    picked = {cs.router.pick(0.0, cs.nodes).node_id for _ in range(4)}
    assert picked == {1}


def test_router_round_robins_when_idle():
    cs = make_cluster(n_nodes=4)
    picked = [cs.router.pick(0.0, cs.nodes).node_id for _ in range(4)]
    assert sorted(picked) == [0, 1, 2, 3]


def test_routed_arrivals_spread_across_nodes():
    cs = make_cluster(shift=False)
    s = cs.run(Workload.longbench_like(80, qps=6.0, seed=0))
    assert s.n_finished == 80
    counts = [len(nd.records) for nd in cs.nodes]
    assert all(c > 0 for c in counts)
    assert max(counts) - min(counts) <= 40    # no starvation


# ---------------------------------------------------------------------------
# hierarchical budget invariants (PowerManager level)
# ---------------------------------------------------------------------------

def test_shrink_budget_is_source_before_sink():
    pm = PowerManager(8, 4000.0, initial_caps=[500.0] * 8)
    t_ready, freed = pm.shrink_budget(0.0, 400.0)
    assert freed == pytest.approx(400.0)
    # watts not released yet: facility accounting still sees the old budget
    assert pm.budget == pytest.approx(4000.0)
    assert t_ready > 0.0                       # cap lowering takes time
    assert sum(pm.commanded) <= 3600.0 + 1e-6  # caps already commanded down
    pm.tick(t_ready)
    pm.commit_budget(t_ready)
    assert pm.budget == pytest.approx(3600.0)
    assert pm._worst_case() <= pm.budget + 1e-6


def test_raise_during_inflight_shrink_respects_target():
    pm = PowerManager(8, 4000.0, initial_caps=[500.0] * 8)
    pm.shrink_budget(0.0, 400.0)
    # a concurrent per-GPU raise may not grab back the promised watts
    for g in range(8):
        pm.set_cap(0.05, g, 750.0)
    assert sum(pm.commanded) <= 3600.0 + 1e-6
    pm.tick(10.0)
    pm.commit_budget(10.0)
    assert pm._worst_case() <= pm.budget + 1e-6


def test_shrink_budget_waits_for_inflight_lowers():
    """Regression: a shrink issued while the node controller's own cap
    lowers are still in flight must not release the watts before those
    lowers land — _worst_case() still counts the old caps."""
    pm = PowerManager(8, 4000.0, initial_caps=[500.0] * 8)
    for g in range(4):
        pm.set_cap(10.0, g, 400.0)          # in flight until 10.3
    t_ready, freed = pm.shrink_budget(10.2, 400.0)
    assert freed == pytest.approx(400.0)
    assert t_ready >= 10.3                   # waits for the pending lowers
    pm.tick(t_ready)
    pm.commit_budget(t_ready)                # must not trip the invariant
    assert pm._worst_case() <= pm.budget + 1e-6


def test_grow_budget_water_fills_past_capped_gpus():
    """A GPU clamped at max_cap rolls its share to GPUs with headroom."""
    pm = PowerManager(2, 1140.0, initial_caps=[400.0, 740.0])
    absorbed = pm.grow_budget(0.0, 100.0)
    assert absorbed == pytest.approx(100.0)
    assert sum(pm.commanded) == pytest.approx(1240.0)
    assert pm.commanded[1] == pytest.approx(750.0)


def test_grow_budget_clamped_by_gpu_ceiling():
    pm = PowerManager(8, 5900.0, initial_caps=[737.5] * 8)
    absorbed = pm.grow_budget(0.0, 500.0)
    assert absorbed == pytest.approx(8 * 750.0 - 5900.0)   # 100 W ceiling room
    assert pm.budget == pytest.approx(6000.0)
    assert all(c <= 750.0 + 1e-9 for c in pm.commanded)


def test_budget_floor_respected():
    pm = PowerManager(8, 3300.0, initial_caps=[412.5] * 8)
    _, freed = pm.shrink_budget(0.0, 1000.0)
    assert freed == pytest.approx(100.0)       # floor is 8 x 400 W
    pm.tick(1.0)
    pm.commit_budget(1.0)
    assert pm.budget == pytest.approx(3200.0)


# ---------------------------------------------------------------------------
# cluster-level invariant during a real run
# ---------------------------------------------------------------------------

def test_facility_budget_invariant_under_shifting():
    cs = make_cluster(ctrl=dyn(ttft_slo=2.0))
    pinned = {
        0: Workload.uniform(60, qps=4.0, in_tokens=8192, out_tokens=128,
                            seed=1, ttft_slo=2.0),
        1: Workload.uniform(60, qps=4.0, in_tokens=500, out_tokens=500,
                            seed=2, tpot_slo=0.020),
    }
    s = cs.run(pinned=pinned)
    assert s.n_finished == 120
    assert len(cs.shift_trace) > 0, "skewed load must trigger budget shifts"
    # invariant also asserted inside the sim on every tick; re-check trace
    assert cs.budget_trace
    for _, budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6
        assert total == pytest.approx(sum(budgets))
    # watts conserved end-to-end
    assert sum(nd.pm.budget for nd in cs.nodes) == \
        pytest.approx(cs.facility_budget_w)


def test_cluster_shift_beats_static_budgets_on_skew():
    def run(shift):
        cs = make_cluster(ctrl=dyn(ttft_slo=2.0), shift=shift)
        pinned = {
            0: Workload.uniform(90, qps=4.0, in_tokens=8192, out_tokens=128,
                                seed=11, ttft_slo=2.0),
            1: Workload.uniform(90, qps=4.0, in_tokens=500, out_tokens=500,
                                seed=12, tpot_slo=0.020),
        }
        return cs.run(pinned=pinned)
    s_static = run(False)
    s_shift = run(True)
    assert s_shift.slo_attainment > s_static.slo_attainment


# ---------------------------------------------------------------------------
# smoke sweep
# ---------------------------------------------------------------------------

def test_two_node_smoke_sweep():
    for ctrl, shift in ((None, False), (dyn(), False), (dyn(), True)):
        cs = make_cluster(ctrl=ctrl, shift=shift)
        s = cs.run(Workload.longbench_like(60, qps=6.0, seed=3))
        assert s.n_finished == s.n_total == 60
        assert 0.0 <= s.slo_attainment <= 1.0
