"""Cluster layer: power-aware routing (capacity-relative, heterogeneous),
hierarchical (facility -> node -> GPU) budget invariants incl. worst-case
accounting during in-flight shifts, cluster-scale role rebalancing
(DynGPU), and end-to-end multi-node behaviour."""
import dataclasses

import pytest

from repro.configs import get_config
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import ControllerConfig, policy_4p4d
from repro.core.costmodel import H100, MI300X
from repro.core.goodput import RequestRecord
from repro.core.power_manager import PowerManager
from repro.core.simulator import SimRequest, Workload

CFG = get_config("llama31_8b")


def dyn(**kw):
    return dataclasses.replace(ControllerConfig(), allow_power=True,
                               allow_gpu=False, **kw)


def make_cluster(n_nodes=2, budget=4000.0, ctrl=None, shift=True,
                 gpu_move=False, **kw):
    return ClusterSimulator(CFG, policy_4p4d(500), n_nodes,
                            node_budget_w=budget, ctrl_cfg=ctrl,
                            cluster_cfg=ClusterConfig(
                                allow_shift=shift, allow_gpu_move=gpu_move),
                            **kw)


# ---------------------------------------------------------------------------
# router dispatch
# ---------------------------------------------------------------------------

def test_router_prefers_less_loaded_node():
    cs = make_cluster()
    # pile queued prefill work onto node 0 only
    for i in range(6):
        cs.nodes[0].submit(SimRequest(RequestRecord(100 + i, 0.0, 8192, 16)))
    assert cs.nodes[0].router_load() > cs.nodes[1].router_load()
    picked = {cs.router.pick(0.0, cs.nodes).node_id for _ in range(4)}
    assert picked == {1}


def test_router_round_robins_when_idle():
    cs = make_cluster(n_nodes=4)
    picked = [cs.router.pick(0.0, cs.nodes).node_id for _ in range(4)]
    assert sorted(picked) == [0, 1, 2, 3]


def test_router_tiebreak_start_rotates():
    """Ties break at a rotating start index: an idle homogeneous cluster is
    an all-way tie every pick, so consecutive picks must walk the nodes in
    order rather than re-picking node 0."""
    cs = make_cluster(n_nodes=3)
    picked = [cs.router.pick(0.0, cs.nodes).node_id for _ in range(6)]
    assert picked == [0, 1, 2, 0, 1, 2]


def test_router_load_is_capacity_relative_across_specs():
    """Equal queued work must weigh heavier on the weaker node: an H100
    prefill pool is slower on an 8k prompt than an MI300X pool, so its
    drain estimate — and hence its router load — is larger."""
    cs = make_cluster(gpu_specs=[MI300X, H100])
    for i in range(6):   # 4 prefill GPUs go busy, 2 requests stay queued
        cs.nodes[0].submit(SimRequest(RequestRecord(100 + i, 0.0, 8192, 16)))
        cs.nodes[1].submit(SimRequest(RequestRecord(200 + i, 0.0, 8192, 16)))
    assert cs.nodes[0].prefill_capacity_tps() > \
        cs.nodes[1].prefill_capacity_tps()
    assert cs.nodes[1].router_load() > cs.nodes[0].router_load()


def test_hetero_routing_with_pinned_arrivals():
    """Pinned arrivals bypass the router entirely; routed traffic lands
    capacity-proportionally, i.e. mostly on the faster MI300X node even
    though the pinned stream keeps that node busier in absolute terms."""
    cs = make_cluster(gpu_specs=[MI300X, H100], shift=False)
    routed = Workload.uniform(60, qps=6.0, in_tokens=8192, out_tokens=32,
                              seed=4, ttft_slo=2.0)
    pinned = {1: Workload.uniform(20, qps=2.0, in_tokens=500, out_tokens=64,
                                  seed=5)}
    s = cs.run(routed, pinned=pinned)
    assert s.n_finished == 80
    assert len(cs.router.trace) == 60        # pinned never hit the router
    routed_counts = [0, 0]
    for _, node_id in cs.router.trace:
        routed_counts[node_id] += 1
    assert routed_counts[0] > routed_counts[1]   # faster pool absorbs more
    assert len(cs.nodes[1].records) == routed_counts[1] + 20


def test_routed_arrivals_spread_across_nodes():
    cs = make_cluster(shift=False)
    s = cs.run(Workload.longbench_like(80, qps=6.0, seed=0))
    assert s.n_finished == 80
    counts = [len(nd.records) for nd in cs.nodes]
    assert all(c > 0 for c in counts)
    assert max(counts) - min(counts) <= 40    # no starvation


# ---------------------------------------------------------------------------
# hierarchical budget invariants (PowerManager level)
# ---------------------------------------------------------------------------

def test_shrink_budget_is_source_before_sink():
    pm = PowerManager(8, 4000.0, initial_caps=[500.0] * 8)
    t_ready, freed = pm.shrink_budget(0.0, 400.0)
    assert freed == pytest.approx(400.0)
    # watts not released yet: facility accounting still sees the old budget
    assert pm.budget == pytest.approx(4000.0)
    assert t_ready > 0.0                       # cap lowering takes time
    assert sum(pm.commanded) <= 3600.0 + 1e-6  # caps already commanded down
    pm.tick(t_ready)
    pm.commit_budget(t_ready)
    assert pm.budget == pytest.approx(3600.0)
    assert pm._worst_case() <= pm.budget + 1e-6


def test_raise_during_inflight_shrink_respects_target():
    pm = PowerManager(8, 4000.0, initial_caps=[500.0] * 8)
    pm.shrink_budget(0.0, 400.0)
    # a concurrent per-GPU raise may not grab back the promised watts
    for g in range(8):
        pm.set_cap(0.05, g, 750.0)
    assert sum(pm.commanded) <= 3600.0 + 1e-6
    pm.tick(10.0)
    pm.commit_budget(10.0)
    assert pm._worst_case() <= pm.budget + 1e-6


def test_shrink_budget_waits_for_inflight_lowers():
    """Regression: a shrink issued while the node controller's own cap
    lowers are still in flight must not release the watts before those
    lowers land — _worst_case() still counts the old caps."""
    pm = PowerManager(8, 4000.0, initial_caps=[500.0] * 8)
    for g in range(4):
        pm.set_cap(10.0, g, 400.0)          # in flight until 10.3
    t_ready, freed = pm.shrink_budget(10.2, 400.0)
    assert freed == pytest.approx(400.0)
    assert t_ready >= 10.3                   # waits for the pending lowers
    pm.tick(t_ready)
    pm.commit_budget(t_ready)                # must not trip the invariant
    assert pm._worst_case() <= pm.budget + 1e-6


def test_grow_budget_water_fills_past_capped_gpus():
    """A GPU clamped at max_cap rolls its share to GPUs with headroom."""
    pm = PowerManager(2, 1140.0, initial_caps=[400.0, 740.0])
    absorbed = pm.grow_budget(0.0, 100.0)
    assert absorbed == pytest.approx(100.0)
    assert sum(pm.commanded) == pytest.approx(1240.0)
    assert pm.commanded[1] == pytest.approx(750.0)


def test_grow_budget_clamped_by_gpu_ceiling():
    pm = PowerManager(8, 5900.0, initial_caps=[737.5] * 8)
    absorbed = pm.grow_budget(0.0, 500.0)
    assert absorbed == pytest.approx(8 * 750.0 - 5900.0)   # 100 W ceiling room
    assert pm.budget == pytest.approx(6000.0)
    assert all(c <= 750.0 + 1e-9 for c in pm.commanded)


def test_budget_floor_respected():
    pm = PowerManager(8, 3300.0, initial_caps=[412.5] * 8)
    _, freed = pm.shrink_budget(0.0, 1000.0)
    assert freed == pytest.approx(100.0)       # floor is 8 x 400 W
    pm.tick(1.0)
    pm.commit_budget(1.0)
    assert pm.budget == pytest.approx(3200.0)


# ---------------------------------------------------------------------------
# cluster-level invariant during a real run
# ---------------------------------------------------------------------------

def test_facility_budget_invariant_under_shifting():
    cs = make_cluster(ctrl=dyn(ttft_slo=2.0))
    pinned = {
        0: Workload.uniform(60, qps=4.0, in_tokens=8192, out_tokens=128,
                            seed=1, ttft_slo=2.0),
        1: Workload.uniform(60, qps=4.0, in_tokens=500, out_tokens=500,
                            seed=2, tpot_slo=0.020),
    }
    s = cs.run(pinned=pinned)
    assert s.n_finished == 120
    assert len(cs.shift_trace) > 0, "skewed load must trigger budget shifts"
    # invariant also asserted inside the sim on every tick; re-check trace
    assert cs.budget_trace
    for _, budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6
        assert total == pytest.approx(sum(budgets))
    # watts conserved end-to-end
    assert sum(nd.pm.budget for nd in cs.nodes) == \
        pytest.approx(cs.facility_budget_w)


def test_cluster_shift_beats_static_budgets_on_skew():
    def run(shift):
        cs = make_cluster(ctrl=dyn(ttft_slo=2.0), shift=shift)
        pinned = {
            0: Workload.uniform(90, qps=4.0, in_tokens=8192, out_tokens=128,
                                seed=11, ttft_slo=2.0),
            1: Workload.uniform(90, qps=4.0, in_tokens=500, out_tokens=500,
                                seed=12, tpot_slo=0.020),
        }
        return cs.run(pinned=pinned)
    s_static = run(False)
    s_shift = run(True)
    assert s_shift.slo_attainment > s_static.slo_attainment


# ---------------------------------------------------------------------------
# cluster-scale DynGPU (role rebalancing)
# ---------------------------------------------------------------------------

def test_request_role_flip_drains_and_publishes():
    from repro.core.simulator import NodeSimulator
    sim = NodeSimulator(CFG, policy_4p4d(500), node_budget_w=4000.0)
    events = []
    sim.loop.subscribe("role_flip", events.append)
    assert sim.can_flip("d2p")
    assert sim.request_role_flip("d2p")
    # the draining GPU leaves the role list immediately (capacity signals
    # and the controller must not count it), flips only after the drain
    assert len(sim.decode_gpus()) == 3
    while sim.loop.heap and not events:
        sim.loop.step()
    node_id, gid, role, external = events[0]
    assert (node_id, role, external) == (0, "prefill", True)
    assert len(sim.prefill_gpus()) == 5
    # flips are refused at the role minimum
    for _ in range(5):
        sim.request_role_flip("d2p")
        while sim.loop.heap:
            sim.loop.step()
    assert len(sim.decode_gpus()) == 1
    assert not sim.can_flip("d2p")
    assert not sim.request_role_flip("d2p")


def test_internal_flip_does_not_clear_coordinator_slot():
    """Regression: a node controller's own role switch publishes the same
    ``role_flip`` topic but with ``external=False`` — it must not release
    the coordinator's one-flip-at-a-time slot or pollute the paired
    flip_done_trace."""
    cs = make_cluster(ctrl=dyn(), gpu_move=True)
    cs._flip_node = 0                   # coordinator drain notionally in flight
    gid = cs.nodes[0]._start_role_switch("d2p")   # node-internal origin
    assert gid is not None
    while cs.loop.heap:
        cs.loop.step()
    assert cs._flip_node == 0
    assert cs.flip_done_trace == []


def test_coordinator_flips_roles_when_watts_exhausted():
    """Skewed hetero load with both nodes stressed: the budget pool dries
    up, so the coordinator must reach for MoveGPU — and every requested
    flip must complete and be accounted in the final role mix."""
    cs = make_cluster(gpu_specs=[MI300X, H100], ctrl=dyn(ttft_slo=2.0),
                      gpu_move=True)
    routed = Workload.uniform(100, qps=8.0, in_tokens=8192, out_tokens=128,
                              seed=5, ttft_slo=2.0)
    pinned = {0: Workload.uniform(50, qps=2.0, in_tokens=500, out_tokens=500,
                                  seed=6, tpot_slo=0.030)}
    s = cs.run(routed, pinned=pinned)
    assert s.n_finished == 150
    assert len(cs.flip_trace) > 0, "watts-exhausted skew must trigger flips"
    assert len(cs.flip_done_trace) == len(cs.flip_trace)
    net_d2p = sum(1 if d == "d2p" else -1 for _, _, d in cs.flip_trace)
    total_prefill = sum(
        sum(1 for g in nd.gpus if g.role == "prefill") for nd in cs.nodes)
    assert total_prefill == 8 + net_d2p
    # role flips move no watts: facility budget conserved end-to-end
    assert sum(nd.pm.budget for nd in cs.nodes) == \
        pytest.approx(cs.facility_budget_w)


def test_cluster_dyngpu_at_least_matches_static_on_skewed_hetero():
    def run(ctrl, shift, gpu_move):
        cs = make_cluster(gpu_specs=[MI300X, H100], ctrl=ctrl, shift=shift,
                          gpu_move=gpu_move)
        routed = Workload.uniform(120, qps=8.0, in_tokens=8192,
                                  out_tokens=128, seed=5, ttft_slo=2.0)
        pinned = {0: Workload.uniform(60, qps=2.0, in_tokens=500,
                                      out_tokens=500, seed=6,
                                      tpot_slo=0.030)}
        return cs.run(routed, pinned=pinned)
    s_static = run(None, False, False)
    s_dyngpu = run(dyn(ttft_slo=2.0), True, True)
    assert s_dyngpu.slo_attainment >= s_static.slo_attainment


def test_facility_invariant_across_inflight_role_flip():
    """Regression: a role-flip drain overlapping a cluster budget handoff
    on the SAME node must keep the facility invariant at every event — the
    post-drain uniform redistribution has to respect the in-flight (lower)
    budget target, not the not-yet-committed old budget."""
    cs = make_cluster(ctrl=dyn(ttft_slo=2.0), gpu_move=True)
    pinned = {0: Workload.uniform(30, qps=4.0, in_tokens=8192,
                                  out_tokens=128, seed=1, ttft_slo=2.0),
              1: Workload.uniform(30, qps=4.0, in_tokens=500,
                                  out_tokens=500, seed=2, tpot_slo=0.020)}
    cs._seed_arrivals(None, pinned)
    for nd in cs.nodes:
        nd.start()
    cs.loop.push(0.0, cs._handle, "cluster_ctrl")
    # start a role flip on node 1, then yank 200 W of its budget mid-drain
    assert cs.nodes[1].request_role_flip("d2p")
    t_ready, freed = cs.nodes[1].pm.shrink_budget(0.0, 200.0)
    assert freed > 0 and cs.nodes[1].pm.budget_op_inflight
    cs.loop.push(t_ready, cs._handle, "budget_ready", (1, 0, freed))
    cs._inflight.update((0, 1))
    flipped = []
    cs.loop.subscribe("role_flip", flipped.append)
    while cs.loop.heap and cs.n_unfinished() > 0:
        cs.loop.step()
        cs.assert_facility_invariant()
    assert flipped, "the drain must complete while budgets moved"
    assert not cs.nodes[1].pm.budget_op_inflight
    assert sum(nd.pm.budget for nd in cs.nodes) == \
        pytest.approx(cs.facility_budget_w)


# ---------------------------------------------------------------------------
# smoke sweep
# ---------------------------------------------------------------------------

def test_two_node_smoke_sweep():
    for ctrl, shift in ((None, False), (dyn(), False), (dyn(), True)):
        cs = make_cluster(ctrl=ctrl, shift=shift)
        s = cs.run(Workload.longbench_like(60, qps=6.0, seed=3))
        assert s.n_finished == s.n_total == 60
        assert 0.0 <= s.slo_attainment <= 1.0
