"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.power_manager import PowerManager
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.serving.ring import KVRing

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402


# ---------------------------------------------------------------------------
# PowerManager: node budget is NEVER exceeded under arbitrary command traces
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 7),
                          st.floats(350, 800),
                          st.floats(0.0, 2.0)), min_size=1, max_size=40))
def test_power_budget_invariant(commands):
    pm = PowerManager(8, 4800.0, initial_caps=[600.0] * 8)
    t = 0.0
    for gpu, watts, dt in commands:
        t += dt
        pm.tick(t)
        pm.set_cap(t, gpu, watts)
        # worst-case draw never exceeds the budget
        assert pm._worst_case() <= 4800.0 + 1e-6
        assert all(400.0 - 1e-9 <= c <= 750.0 + 1e-9 for c in pm.commanded)
    pm.tick(t + 10.0)
    assert sum(pm.effective) <= 4800.0 + 1e-6


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 7), st.floats(10, 300))
def test_power_shift_conserves_budget(n_src, watts):
    pm = PowerManager(8, 4800.0, initial_caps=[600.0] * 8)
    src = list(range(n_src))
    dst = list(range(n_src, 8))
    t_ready, freed = pm.shift(0.0, src, dst, watts)
    assert pm._worst_case() <= 4800.0 + 1e-6
    pm.tick(t_ready)
    pm.apply_raise(t_ready, dst, freed)
    assert pm._worst_case() <= 4800.0 + 1e-6
    assert sum(pm.commanded) <= 4800.0 + 1e-6


# ---------------------------------------------------------------------------
# KV ring buffer: conservation + FIFO of ready slots
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=200),
       st.integers(1, 8))
def test_ring_conservation(ops, n_slots):
    ring = KVRing(n_slots)
    put_seq = 0
    pulled = []
    for is_put in ops:
        if is_put:
            idx = ring.try_put(put_seq)
            if idx is not None:
                put_seq += 1
        else:
            out = ring.try_pull()
            if out is not None:
                pulled.append(out)
        assert ring.n_free + ring.n_ready <= n_slots
    assert pulled == sorted(pulled)          # FIFO
    assert len(pulled) + ring.n_ready == put_seq


# ---------------------------------------------------------------------------
# RG-LRU scan: kernel == sequential reference on random shapes
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3),
       st.sampled_from([64, 128, 256]),
       st.sampled_from([128, 256]),
       st.integers(0, 1000))
def test_rglru_random(B, S, W, seed):
    key = jax.random.key(seed)
    ks = jax.random.split(key, 3)
    la = -jnp.abs(jax.random.normal(ks[0], (B, S, W))) * 0.3
    x = jax.random.normal(ks[1], (B, S, W))
    h0 = jax.random.normal(ks[2], (B, W))
    out = rglru_scan(la, x, h0, chunk=64, bw=128)
    ref = rglru_scan_ref(la, x, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# goodput metric sanity
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 10), st.floats(0.01, 3.0),
                          st.floats(0.001, 0.2), st.integers(2, 300)),
                min_size=1, max_size=50))
def test_goodput_bounds(reqs):
    from repro.core.goodput import RequestRecord, summarize
    records = []
    for i, (arr, ttft_off, tpot, out) in enumerate(reqs):
        r = RequestRecord(i, arr, 100, out)
        r.prefill_done = arr + ttft_off
        r.finish = r.prefill_done + tpot * (out - 1)
        records.append(r)
    s = summarize(records, duration_s=20.0, avg_provisioned_w=4800.0)
    assert 0.0 <= s.slo_attainment <= 1.0
    assert s.n_good <= s.n_finished == len(records)
    # manual check
    manual = sum(1 for r in records
                 if r.ttft <= 1.0 + 1e-9 and r.tpot <= 0.040 + 1e-9)
    assert s.n_good == manual


# ---------------------------------------------------------------------------
# cost model: monotone in power, KV transfer in TPOT accounting
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.floats(400, 740), st.floats(5, 300))
def test_costmodel_monotone_in_power(cap, extra):
    from repro.configs import get_config
    from repro.core.costmodel import MI300X, CostModel
    from repro.core.power_model import mi300x
    cm = CostModel(get_config("llama31_8b"), MI300X, mi300x())
    hi = min(cap + extra, 750.0)
    assert cm.prefill_time(4096, cap) >= cm.prefill_time(4096, hi) - 1e-12
    assert cm.decode_step_time(32, 4096, cap) >= \
        cm.decode_step_time(32, 4096, hi) - 1e-12


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 64), st.integers(128, 16384))
def test_decode_time_monotone_in_batch_and_ctx(batch, ctx):
    from repro.configs import get_config
    from repro.core.costmodel import MI300X, CostModel
    from repro.core.power_model import mi300x
    cm = CostModel(get_config("llama31_8b"), MI300X, mi300x())
    t = cm.decode_step_time(batch, ctx, 600)
    assert cm.decode_step_time(batch + 1, ctx, 600) >= t - 1e-12
    assert cm.decode_step_time(batch, ctx + 512, 600) >= t - 1e-12
    # throughput (tokens/s) must not decrease with batch
    assert (batch + 1) / cm.decode_step_time(batch + 1, ctx, 600) >= \
        batch / t - 1e-9


# ---------------------------------------------------------------------------
# sanitizer: hierarchical power conservation holds under random node churn
# and controller role flips (the runtime half of simcheck — the
# InvariantSanitizer validates every dispatch and raises on violation)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["fail", "leave", "join"]),
                          st.floats(0.5, 25.0)),
                min_size=1, max_size=3),
       st.integers(0, 999))
def test_churn_roleflip_power_conservation(events, seed):
    import dataclasses

    from repro.configs import get_config
    from repro.core.cluster import ClusterConfig, ClusterSimulator
    from repro.core.controller import ControllerConfig, policy_4p4d
    from repro.core.fleet import FleetConfig, FleetManager
    from repro.core.simulator import Workload

    ctrl = dataclasses.replace(ControllerConfig(), allow_power=True,
                               allow_gpu=True, ttft_slo=2.0)
    cs = ClusterSimulator(get_config("llama31_8b"), policy_4p4d(500), 3,
                          node_budget_w=4000.0, ctrl_cfg=ctrl,
                          cluster_cfg=ClusterConfig(allow_shift=True),
                          sanitize=True)
    fm = FleetManager(cs, FleetConfig(elastic=True))
    gone = set()
    for i, (kind, t) in enumerate(sorted(events, key=lambda e: e[1])):
        nid = i % 3
        if kind == "join":
            if nid in gone:                 # rejoin a departed node
                fm.schedule_join(t, nid)
                gone.discard(nid)
        elif nid not in gone and len(gone) < 2:   # keep >= 1 node alive
            (fm.schedule_fail if kind == "fail" else fm.schedule_leave)(t, nid)
            gone.add(nid)
    wl = Workload.uniform(30, qps=4.0, in_tokens=2048, out_tokens=64,
                          seed=seed)
    # every dispatch is validated: a conservation / causality / residency /
    # energy break anywhere in the churn+role-flip machinery raises here
    cs.run(wl)
    assert cs.loop.sanitizer.checks > 0
    cs.assert_facility_invariant()


# ---------------------------------------------------------------------------
# autoscaler: the decision loop never violates facility power conservation,
# whatever workload shape / tariff / config it is handed — every membership
# op it issues goes through the same source-before-sink machinery, and the
# sanitizer validates every dispatch along the way
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.sampled_from(["predictive", "reactive"]),
       st.floats(2.0, 10.0),      # trough arrival rate
       st.floats(12.0, 24.0),     # peak arrival rate
       st.floats(0.05, 0.60),     # off-peak electricity price
       st.integers(0, 999))
def test_autoscaler_power_conservation(mode, trough, peak, price, seed):
    import dataclasses

    from repro.configs import get_config
    from repro.core.autoscale import (AutoscaleConfig, PredictiveAutoscaler,
                                      SignalTrace)
    from repro.core.cluster import ClusterConfig, ClusterSimulator
    from repro.core.controller import ControllerConfig, policy_4p4d
    from repro.core.fleet import FleetConfig, FleetManager
    from repro.core.simulator import Workload

    ctrl = dataclasses.replace(ControllerConfig(), allow_power=True,
                               ttft_slo=2.0)
    cs = ClusterSimulator(get_config("llama31_8b"), policy_4p4d(500), 3,
                          node_budget_w=4000.0, ctrl_cfg=ctrl,
                          cluster_cfg=ClusterConfig(allow_shift=True),
                          seed=seed, router_policy="cost", sanitize=True)
    fm = FleetManager(cs, FleetConfig(elastic=True), standby=(2,))
    asc = PredictiveAutoscaler(
        fm, AutoscaleConfig(mode=mode, period_s=2.0, window_s=12.0,
                            holdoff_s=4.0, season_s=20.0),
        price_trace=SignalTrace([0.0, 8.0, 20.0],
                                [price, 3.0 * price, price]),
        carbon_trace=SignalTrace([0.0], [400.0]))
    asc.start()
    wl = Workload.phased_mix([
        Workload.uniform(20, qps=trough, in_tokens=2048, out_tokens=64,
                         seed=seed, ttft_slo=2.0),
        Workload.uniform(60, qps=peak, in_tokens=2048, out_tokens=64,
                         seed=seed + 1, ttft_slo=2.0)])
    # every dispatch is validated; any budget over-commit the decision
    # loop could provoke (join during drain, leave of the power sink, ...)
    # raises inside the run
    cs.run(wl)
    assert cs.loop.sanitizer.checks > 0
    cs.assert_facility_invariant()
    for t, budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6, (t, budgets)


# ---------------------------------------------------------------------------
# Chaos schedules: power conservation + KV single-residency survive
# randomized emergencies x correlated failures x lossy migrations
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 999),           # chaos layout seed
       st.floats(0.45, 0.9),          # emergency depth (frac of nameplate)
       st.integers(1, 2),             # correlated rack size
       st.integers(0, 3),             # link faults
       st.booleans())                 # retries on (degraded) vs off (naive)
def test_chaos_schedule_invariants(seed, frac, rack, n_links, retries):
    import dataclasses

    from repro.configs import get_config
    from repro.core.chaos import ChaosConfig, ChaosEngine
    from repro.core.cluster import (AdmissionConfig, ClusterConfig,
                                    ClusterSimulator)
    from repro.core.controller import ControllerConfig, policy_4p4d
    from repro.core.fleet import FleetConfig, FleetManager
    from repro.core.simulator import Workload

    ctrl = dataclasses.replace(ControllerConfig(), allow_power=True,
                               ttft_slo=2.0)
    cs = ClusterSimulator(get_config("llama31_8b"), policy_4p4d(500), 3,
                          node_budget_w=4000.0, ctrl_cfg=ctrl,
                          cluster_cfg=ClusterConfig(allow_shift=True),
                          seed=seed, sanitize=True,
                          admission=AdmissionConfig(slo_aware=True))
    fm = FleetManager(cs, FleetConfig(
        migrate_max_retries=4 if retries else 0))
    ch = ChaosEngine(fm, ChaosConfig(seed=seed))
    ch.inject(horizon_s=8.0, emergency_frac=(frac, frac),
              rack_size=rack, rejoin_after_s=2.5,
              n_link_faults=n_links, link_fault_s=0.4)
    # the sanitizer validates hierarchical power conservation AND KV
    # single-residency at EVERY dispatch; a violation raises mid-run
    cs.run(Workload.uniform(30, qps=5.0, in_tokens=2048, out_tokens=64,
                            seed=seed, ttft_slo=2.0))
    assert cs.loop.sanitizer.checks > 0
    cs.assert_facility_invariant()
    for t, budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6, (t, budgets)
    # the ledger terminally resolves: finished or shed, nothing stranded
    assert cs.n_unfinished() == 0
    for r in cs.records:
        assert (r.finish is not None) or (r.shed_t is not None)


# ---------------------------------------------------------------------------
# Multi-tenancy: random tenant mixes under priority preemption + affinity
# routing + prefix caching keep power conservation, prefix-block
# single-residency, and the no-silent-drop guarantee (sanitizer validates
# every dispatch), and per-tenant attribution never loses a record
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(0, 999),            # workload seed
       st.integers(0, 3),              # high-priority tenant's priority edge
       st.integers(2, 6),              # decode slots per GPU (saturation)
       st.booleans())                  # preemption on vs off
def test_tenant_mix_preemption_affinity_invariants(seed, pri, slots, preempt):
    import dataclasses

    from repro.configs import get_config
    from repro.core.cluster import ClusterSimulator
    from repro.core.controller import policy_4p4d
    from repro.core.costmodel import MI300X
    from repro.core.prefixcache import PrefixCacheConfig
    from repro.core.simulator import Workload
    from repro.core.tenancy import TenantRegistry, TenantSpec

    reg = TenantRegistry([TenantSpec("vip", priority=pri, weight=2.0),
                          TenantSpec("batch", priority=0, weight=0.5)],
                         preempt=preempt)
    cs = ClusterSimulator(get_config("llama31_8b"), policy_4p4d(500), 2,
                          node_budget_w=4000.0, seed=seed, sanitize=True,
                          gpu=dataclasses.replace(MI300X,
                                                  max_active_decode=slots),
                          router_policy="affinity", tenancy=reg,
                          cache_cfg=PrefixCacheConfig())
    wl = Workload(
        Workload.sessions(6, turns=3, qps=2.0, tenant="vip",
                          seed=seed).entries
        + Workload.uniform(18, qps=8.0, in_tokens=1536, out_tokens=256,
                           seed=seed + 1, tenant="batch").entries)
    # every dispatch validated: conservation, prefix-block residency,
    # preempt no-silent-drop — a break anywhere raises inside the run
    cs.run(wl)
    assert cs.loop.sanitizer.checks > 0
    cs.assert_facility_invariant()
    assert cs.n_unfinished() == 0
    # per-tenant attribution is a partition of the ledger
    s = cs.summary()
    by_tenant = {"vip": 0, "batch": 0}
    for r in cs.records:
        by_tenant[r.tenant] += 1
    assert by_tenant["vip"] == s.per_tenant["vip"]["n_total"] == 18
    assert by_tenant["batch"] == s.per_tenant["batch"]["n_total"] == 18
    if not preempt:
        assert all(not nd.preempt_trace for nd in cs.nodes)
