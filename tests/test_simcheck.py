"""simcheck static pass: fixture-driven positive/negative tests for each
rule (RC001-RC007), fingerprint stability under line moves, baseline
round-trip/staleness, CLI exit codes, and the repo-tree-is-clean gate."""
import textwrap
from pathlib import Path

from repro.analysis.check.__main__ import main as simcheck_main
from repro.analysis.check.baseline import (load_baseline, split_by_baseline,
                                           write_baseline)
from repro.analysis.check.rules import Severity, check_paths, check_source

CORE = Path("src/repro/core/cluster.py")        # in_core, not RC003 scope
PM = Path("src/repro/core/power_manager.py")    # the RC001 writer home
SIM = Path("src/repro/core/simulator.py")       # RC003 scope
OUT = Path("src/repro/serving/engine.py")       # outside core/


def rc(source, path, rule):
    return [f for f in check_source(textwrap.dedent(source), path)
            if f.rule == rule]


# ---------------------------------------------------------------------------
# RC001: budget/cap writes only through the conservation API
# ---------------------------------------------------------------------------

def test_rc001_flags_budget_write_outside_api():
    fs = rc("""
        class Coordinator:
            def rebalance(self, node) -> None:
                node.pm.budget = 1000.0
    """, CORE, "RC001")
    assert len(fs) == 1
    assert fs[0].severity is Severity.ERROR
    assert fs[0].qualname == "Coordinator.rebalance"
    assert "budget" in fs[0].message


def test_rc001_flags_cap_writes_outside_api():
    fs = rc("""
        def fix(pm) -> None:
            pm.commanded[0] = 500.0
            pm.effective = [0.0] * 8
    """, OUT, "RC001")
    assert len(fs) == 2


def test_rc001_flags_non_writer_method_inside_power_manager():
    # tick may write caps but NOT budget state
    fs = rc("""
        class PowerManager:
            def tick(self, now: float) -> None:
                self.budget = 0.0
    """, PM, "RC001")
    assert len(fs) == 1


def test_rc001_allows_the_conservation_api():
    fs = rc("""
        class PowerManager:
            def __init__(self) -> None:
                self.budget = 4000.0
                self.commanded = [500.0] * 8
            def shrink_budget(self, now: float, watts: float) -> None:
                self._budget_target = self.budget - watts
            def commit_budget(self, now: float) -> None:
                self.budget = self._budget_target
            def set_cap(self, now: float, g: int, w: float) -> None:
                self.commanded[g] = w
    """, PM, "RC001")
    assert fs == []


# ---------------------------------------------------------------------------
# RC002: no wall clock / unseeded randomness in core/
# ---------------------------------------------------------------------------

def test_rc002_flags_wallclock_and_unseeded_randomness():
    fs = rc("""
        import random
        import time
        import numpy as np

        def jitter() -> float:
            return time.time() + random.random() + float(np.random.rand())
    """, CORE, "RC002")
    assert sorted(f.token for f in fs) == \
        ["np.random.rand", "random.random", "time.time"]


def test_rc002_allows_seeded_rng_and_ignores_non_core():
    ok = """
        import numpy as np

        def gen(seed: int) -> object:
            return np.random.default_rng(seed)
    """
    assert rc(ok, CORE, "RC002") == []
    bad = """
        import time

        def stamp() -> float:
            return time.time()
    """
    assert rc(bad, OUT, "RC002") == []      # outside core/: legal


# ---------------------------------------------------------------------------
# RC003: no float '+=' accumulation loops in simulator.py / fleet.py
# ---------------------------------------------------------------------------

def test_rc003_flags_float_accumulator_in_loop():
    fs = rc("""
        def total(steps) -> float:
            e_j = 0.0
            for s in steps:
                e_j += s.dt * s.watts
            return e_j
    """, SIM, "RC003")
    assert len(fs) == 1
    assert "e_j" in fs[0].message and "cumsum" in fs[0].message


def test_rc003_exempts_counters_and_per_item_writes():
    fs = rc("""
        def drain(reqs, dt) -> int:
            n = 0
            for r in reqs:
                n += 1           # integer counter: exact arithmetic
                r.t_end += dt    # per-item write keyed by the loop var
            return n
    """, SIM, "RC003")
    assert fs == []


def test_rc003_scope_is_simulator_and_fleet_only():
    acc = """
        def total(steps) -> float:
            e_j = 0.0
            for s in steps:
                e_j += s.dt
            return e_j
    """
    assert rc(acc, CORE, "RC003") == []     # cluster.py: out of scope


# ---------------------------------------------------------------------------
# RC004: every EventLoop post provably >= now
# ---------------------------------------------------------------------------

def test_rc004_flags_constant_time_push():
    fs = rc("""
        class Node:
            def kick(self) -> None:
                self.loop.push(5.0, self.handle, "tick")
    """, OUT, "RC004")
    assert len(fs) == 1
    assert fs[0].token == "push(5.0)"
    assert fs[0].qualname == "Node.kick"


def test_rc004_accepts_now_derived_and_time_returning_expressions():
    fs = rc("""
        class Node:
            def later(self, dt: float) -> None:
                self.loop.push(self.loop.now + dt, self.handle, "a")

            def clamped(self, t: float) -> None:
                t = max(t, self.loop.now)
                self.loop.push(t, self.handle, "b")

            def after_shift(self, pm) -> None:
                t_ready, freed = pm.shift(0.0, [0], [1], 50.0)
                self.loop.push(t_ready, self.handle, "c")
    """, OUT, "RC004")
    assert fs == []


# ---------------------------------------------------------------------------
# RC005: public core/ APIs fully annotated
# ---------------------------------------------------------------------------

def test_rc005_flags_unannotated_public_core_api():
    fs = rc("""
        def api(x):
            return x

        class Sim:
            def step(self, dt) -> None:
                pass

            def _helper(self, y):
                pass

        class _Hidden:
            def meth(self, z):
                pass
    """, CORE, "RC005")
    assert sorted(f.token for f in fs) == ["def api", "def step"]
    msgs = {f.token: f.message for f in fs}
    assert "return type" in msgs["def api"]
    assert "parameters dt" in msgs["def step"]


def test_rc005_ignores_non_core_and_fully_annotated():
    src = """
        def api(x):
            return x
    """
    assert rc(src, OUT, "RC005") == []
    ok = """
        class Sim:
            def step(self, dt: float) -> None:
                pass
    """
    assert rc(ok, CORE, "RC005") == []


# ---------------------------------------------------------------------------
# RC006: fault injection in core/ only through the ChaosEngine API
# ---------------------------------------------------------------------------

CHAOS = Path("src/repro/core/chaos.py")


def test_rc006_flags_hook_install_in_core():
    fs = rc("""
        def arm(fleet) -> None:
            fleet.link_fault_fn = my_hook
    """, CORE, "RC006")
    assert len(fs) == 1
    assert "link_fault_fn" in fs[0].message


def test_rc006_flags_chaos_engine_built_in_core():
    fs = rc("""
        def run(fleet) -> None:
            ch = chaos.ChaosEngine(fleet)
    """, CORE, "RC006")
    assert len(fs) == 1
    assert "ChaosEngine" in fs[0].token


def test_rc006_allows_chaos_module_none_reset_and_non_core():
    install = """
        def arm(self) -> None:
            self.fm.link_fault_fn = self._link_fault
            eng = ChaosEngine(self.fm)
    """
    assert rc(install, CHAOS, "RC006") == []     # chaos.py owns the hook
    assert rc(install, OUT, "RC006") == []       # outside core/: callers may
    declare = """
        class FleetManager:
            def __init__(self) -> None:
                self.link_fault_fn = None

            def reset(self) -> None:
                self.link_fault_fn = None
    """
    assert rc(declare, CORE, "RC006") == []      # declare/clear is legal


# ---------------------------------------------------------------------------
# RC007: prefix-cache / tenant state written only through the mutation API
# ---------------------------------------------------------------------------

PFX = Path("src/repro/core/prefixcache.py")
TEN = Path("src/repro/core/tenancy.py")


def test_rc007_flags_cache_state_writes_outside_api():
    fs = rc("""
        def warm(node, key) -> None:
            node.prefix_cache._radix[key] = None
            node.prefix_cache._used_tokens = 0
    """, CORE, "RC007")
    assert len(fs) == 2
    assert all(f.severity is Severity.ERROR for f in fs)
    assert "PrefixCache" in fs[0].message


def test_rc007_flags_tenant_and_delete_writes():
    fs = rc("""
        def reset(reg, name) -> None:
            reg._admitted[name] = 0
            del reg._tenants[name]
    """, OUT, "RC007")
    assert len(fs) == 2
    assert "TenantRegistry" in fs[0].message


def test_rc007_flags_non_writer_method_inside_the_class():
    # a read-side helper may not mutate the radix
    fs = rc("""
        class PrefixCache:
            def match_tokens(self, path: tuple) -> int:
                self._clock += 1
                return 0
    """, PFX, "RC007")
    assert len(fs) == 1


def test_rc007_allows_the_mutation_api():
    cache_ok = """
        class PrefixCache:
            def __init__(self) -> None:
                self._radix = {}
                self._used_tokens = 0
            def insert(self, path: tuple, segs: tuple) -> None:
                self._radix[path] = segs
                self._used_tokens += 1
            def pop_leaf(self, path: tuple) -> None:
                del self._radix[path]
            def _evict_to_fit(self, n: int) -> None:
                self._used_tokens -= n
    """
    assert rc(cache_ok, PFX, "RC007") == []
    reg_ok = """
        class TenantRegistry:
            def __init__(self) -> None:
                self._tenants = {}
                self._admitted = {}
            def note_admit(self, name: str) -> None:
                self._admitted[name] = self._admitted.get(name, 0) + 1
    """
    assert rc(reg_ok, TEN, "RC007") == []


# ---------------------------------------------------------------------------
# fingerprints, baseline, CLI
# ---------------------------------------------------------------------------

PUSH_SRC = ("class Node:\n"
            "    def kick(self) -> None:\n"
            "        self.loop.push(5.0, self.handle, 't')\n")


def test_fingerprint_survives_line_moves():
    fa = [f for f in check_source(PUSH_SRC, OUT) if f.rule == "RC004"]
    fb = [f for f in check_source("\n\n# moved\n" + PUSH_SRC, OUT)
          if f.rule == "RC004"]
    assert fa[0].line != fb[0].line
    assert fa[0].fingerprint == fb[0].fingerprint


def test_path_normalized_to_repro_root():
    fs = rc("def api(x):\n    return x\n",
            Path("/somewhere/else/src/repro/core/x.py"), "RC005")
    assert fs[0].path == "repro/core/x.py"


def test_baseline_roundtrip_and_staleness(tmp_path):
    findings = check_source(PUSH_SRC, OUT)
    bl = tmp_path / "baseline.txt"
    assert write_baseline(bl, findings) == len(findings) == 1
    entries = load_baseline(bl)
    new, suppressed, stale = split_by_baseline(findings, entries)
    assert new == [] and len(suppressed) == 1 and stale == set()
    entries.add("RC001 repro/gone.py::<module>::x.budget = 1")
    new, suppressed, stale = split_by_baseline(findings, entries)
    assert new == [] and len(stale) == 1


def test_baseline_missing_file_is_empty(tmp_path):
    assert load_baseline(tmp_path / "nope.txt") == set()


def test_cli_exit_codes(tmp_path, capsys):
    mod = tmp_path / "mod.py"
    mod.write_text(PUSH_SRC)
    bl = tmp_path / "bl.txt"
    assert simcheck_main([str(mod), "--baseline", str(bl)]) == 1
    assert "RC004" in capsys.readouterr().out
    assert simcheck_main([str(mod), "--baseline", str(bl),
                          "--update-baseline"]) == 0
    assert simcheck_main([str(mod), "--baseline", str(bl)]) == 0
    assert simcheck_main([str(mod), "--baseline", str(bl),
                          "--no-baseline"]) == 1


def test_cli_stale_baseline_entries_fail(tmp_path, capsys):
    """A baseline entry whose finding no longer exists is debt-list rot:
    the CLI must fail on it, and ``--allow-stale`` must downgrade it back
    to a warning (escape hatch for mid-refactor runs)."""
    mod = tmp_path / "mod.py"
    mod.write_text(PUSH_SRC)
    bl = tmp_path / "bl.txt"
    assert simcheck_main([str(mod), "--baseline", str(bl),
                          "--update-baseline"]) == 0
    # fix lands: the finding disappears, its baseline entry goes stale
    mod.write_text("def api(x: int) -> int:\n    return x\n")
    capsys.readouterr()
    assert simcheck_main([str(mod), "--baseline", str(bl)]) == 1
    assert "stale baseline entry" in capsys.readouterr().out
    assert simcheck_main([str(mod), "--baseline", str(bl),
                          "--allow-stale"]) == 0


def test_repo_tree_is_clean_against_checked_in_baseline():
    repo = Path(__file__).resolve().parents[1]
    findings, n_files = check_paths([str(repo / "src")])
    baseline = load_baseline(repo / "simcheck-baseline.txt")
    new, _suppressed, stale = split_by_baseline(findings, baseline)
    assert n_files > 0
    assert [f.render() for f in new] == []
    assert stale == set()
