import numpy as np
import pytest

# Modules whose tests are marked ``slow`` wholesale and run only in the CI
# slow lane (the fast lane runs ``pytest -m "not slow"``).
# test_dist_attention spawns a subprocess with 8 XLA host devices and takes
# ~8 minutes on CPU — by far the longest item in the suite.
SLOW_MODULES = {"test_dist_attention"}


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.module.__name__ in SLOW_MODULES:
            item.add_marker(pytest.mark.slow)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
