"""Configs (assigned table fidelity) + HLO analysis utilities."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import hlo_graph as HG
from repro.configs import ARCH_ALIASES, ARCH_IDS, INPUT_SHAPES, get_config

EXPECTED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "qwen1_5_4b": (40, 2560, 20, 20, 6912, 151936),
    "granite_3_8b": (40, 4096, 32, 8, 12800, 49155),
    "llama3_405b": (126, 16384, 128, 8, 53248, 128256),
    "starcoder2_15b": (40, 6144, 48, 4, 24576, 49152),
    "llama4_maverick": (48, 5120, 40, 8, 8192, 202048),
    "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
    "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
    "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
    "phi3_5_moe": (32, 4096, 32, 8, 6400, 32064),
    "chameleon_34b": (48, 8192, 64, 8, 22016, 65536),
}

PARAM_RANGES = {
    "qwen1_5_4b": (3.5e9, 4.5e9),
    "granite_3_8b": (7.5e9, 9e9),
    "llama3_405b": (3.9e11, 4.2e11),
    "starcoder2_15b": (1.45e10, 1.7e10),
    "llama4_maverick": (3.8e11, 4.2e11),
    "phi3_5_moe": (4.0e10, 4.4e10),
    "chameleon_34b": (3.2e10, 3.6e10),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assigned_config_exact(arch):
    c = get_config(arch)
    L, D, H, K, F, V = EXPECTED[arch]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (L, D, H, K, F, V)
    assert c.source, "every config must cite its source"


@pytest.mark.parametrize("arch,rng_", list(PARAM_RANGES.items()))
def test_param_counts_plausible(arch, rng_):
    lo, hi = rng_
    assert lo <= get_config(arch).param_count() <= hi


def test_moe_active_params():
    c = get_config("llama4_maverick")
    assert 1.6e10 <= c.active_param_count() <= 1.8e10     # "A17B"
    c = get_config("phi3_5_moe")
    assert 6.0e9 <= c.active_param_count() <= 7.2e9       # "A6.6B"


def test_aliases_resolve():
    for alias in ARCH_ALIASES:
        assert get_config(alias) is not None


def test_input_shapes_table():
    s = INPUT_SHAPES
    assert s["train_4k"].global_batch == 256
    assert s["long_500k"].seq_len == 524_288
    assert s["decode_32k"].kind == "decode"


def test_reduced_configs_small():
    for arch in ARCH_IDS:
        r = get_config(arch).reduced()
        assert r.n_layers <= 4 and r.d_model <= 512
        if r.n_experts:
            assert r.n_experts <= 4


# --- HLO graph analysis -------------------------------------------------------

def test_trip_count_multiplication():
    D, G = 64, 7

    def f(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        x, _ = jax.lax.scan(body, x, w)
        return x.sum()

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((G, D, D), jnp.float32),
        jax.ShapeDtypeStruct((4, D), jnp.float32)).compile()
    mc = HG.analyze(comp.as_text())
    assert mc.dot_flops == pytest.approx(2 * 4 * D * D * G, rel=0.01)
    assert mc.loops and mc.loops[0][1] == G


_INLINE_SHAPE_HLO = """
HloModule m

ENTRY %main (p0: f32[4,64], p1: f32[64,96]) -> f32[4,96] {
  %p0 = f32[4,64]{1,0} parameter(0)
  %p1 = f32[64,96]{1,0} parameter(1)
  ROOT %dot.1 = f32[4,96]{1,0} dot(f32[4,64]{1,0:T(8,128)} %p0, f32[64,96]{1,0} %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_dot_parsing_with_inline_operand_shapes():
    """Regression: the operand regex used to match the dtype token (``f32``)
    of inline operand shapes, so contraction size collapsed to 1 and dot
    FLOPs were undercounted by the full contraction dimension. The inline
    operand shape (here with a TPU tiled layout, which nests parens) must be
    read directly."""
    mc = HG.analyze(_INLINE_SHAPE_HLO)
    assert mc.dot_flops == pytest.approx(2 * 4 * 96 * 64)


def test_dot_parsing_falls_back_to_defining_op():
    txt = """
HloModule m

ENTRY %main (p0: f32[8,32], p1: f32[32,16]) -> f32[8,16] {
  %p0 = f32[8,32]{1,0} parameter(0)
  %p1 = f32[32,16]{1,0} parameter(1)
  ROOT %dot.2 = f32[8,16]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    mc = HG.analyze(txt)
    assert mc.dot_flops == pytest.approx(2 * 8 * 16 * 32)


def test_async_collective_suffix_stripped_not_rstripped():
    """``rstrip("-start")`` strips a character *set*; the opcode must lose
    only a literal ``-start``/``-done`` suffix, and ``-done`` halves of async
    pairs must not be double-counted."""
    assert HG._strip_async_suffix("all-reduce-start") == "all-reduce"
    assert HG._strip_async_suffix("all-reduce-done") == "all-reduce"
    assert HG._strip_async_suffix("reduce-scatter") == "reduce-scatter"
    assert HG._strip_async_suffix("all-to-all") == "all-to-all"
    txt = """
HloModule m

ENTRY %main (p0: f32[128]) -> f32[128] {
  %p0 = f32[128]{0} parameter(0)
  %ar-start = f32[128]{0} all-reduce-start(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %ar-done = f32[128]{0} all-reduce-done(%ar-start)
}
"""
    mc = HG.analyze(txt)
    assert mc.coll_counts.get("all-reduce") == 1


def test_wire_factors():
    assert HG._wire_factor("all-reduce", 16) == pytest.approx(2 * 15 / 16)
    assert HG._wire_factor("all-gather", 16) == pytest.approx(15 / 16)
    assert HG._wire_factor("reduce-scatter", 16) == 15.0
    assert HG._wire_factor("collective-permute", 16) == 1.0
