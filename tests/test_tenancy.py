"""Multi-tenant subsystem units: the prefix cache's radix/LRU/closure
mechanics, the tenant registry, per-tenant goodput attribution, and
single-node priority preemption + prefix reuse end to end (sanitized)."""
import dataclasses

from repro.configs import get_config
from repro.core.controller import StaticPolicy, policy_4p4d
from repro.core.costmodel import MI300X
from repro.core.goodput import RequestRecord, summarize
from repro.core.prefixcache import (PrefixBlock, PrefixCache,
                                    PrefixCacheConfig)
from repro.core.simulator import NodeSimulator, Workload
from repro.core.tenancy import TenantRegistry, TenantSpec

CFG = get_config("llama31_8b")


# ---------------------------------------------------------------------------
# PrefixCache: radix mechanics
# ---------------------------------------------------------------------------

def test_cache_insert_then_lookup_hits_whole_path():
    pc = PrefixCache(0, capacity_tokens=1000)
    pc.insert(("sys", "a"), (512, 256))
    assert pc.used_tokens == 768
    assert len(pc) == 2
    assert pc.lookup(("sys", "a")) == 768
    assert pc.lookup(("sys", "b")) == 512          # shared prefix only
    assert pc.lookup(("other",)) == 0
    assert pc.hits == 2 and pc.misses == 1


def test_cache_match_tokens_does_not_touch_lru():
    pc = PrefixCache(0, capacity_tokens=1000)
    pc.insert(("sys",), (512,))
    clock = pc._clock
    assert pc.match_tokens(("sys", "x")) == 512
    assert pc._clock == clock                      # read side: no LRU writes


def test_cache_lru_evicts_childless_cold_entries_only():
    pc = PrefixCache(0, capacity_tokens=600)
    pc.insert(("sys", "a"), (256, 256))            # sys hot via a's insert
    pc.insert(("sys", "b"), (256, 256))            # needs 256: evict a leaf
    paths = {p for p, _ in pc.entries()}
    # interior ("sys",) is load-bearing (children) and never evicted
    assert ("sys",) in paths
    assert ("sys", "b") in paths
    assert ("sys", "a") not in paths               # coldest childless leaf
    assert pc.evictions == 1
    assert pc.used_tokens == 512 <= pc.capacity_tokens


def test_cache_prefix_closure_always_holds():
    pc = PrefixCache(0, capacity_tokens=5000)
    pc.insert(("a", "b", "c"), (100, 100, 100))
    for path, _ in pc.entries():
        assert len(path) == 1 or path[:-1] in dict(pc.entries())


def test_cache_oversized_segment_skipped_with_descendants():
    pc = PrefixCache(0, capacity_tokens=300)
    pc.insert(("sys", "huge", "tail"), (100, 400, 50))
    paths = {p for p, _ in pc.entries()}
    assert paths == {("sys",)}                     # branch stops at 400 > cap
    assert pc.used_tokens == 100


def test_cache_pop_leaf_and_adopt_preserve_identity():
    src = PrefixCache(0, capacity_tokens=1000)
    src.insert(("sys", "s0"), (512, 256))
    assert src.pop_leaf(("sys",)) is None          # interior: stays
    blk = src.pop_leaf(("sys", "s0"))
    assert isinstance(blk, PrefixBlock)
    assert blk.seg_tokens == 256 and src.used_tokens == 512
    dst = PrefixCache(1, capacity_tokens=1000)
    assert not dst.adopt(blk)                      # parent missing: refused
    dst.insert(("sys",), (512,))
    assert dst.adopt(blk)
    assert dict(dst.entries())[("sys", "s0")].block_id == blk.block_id
    assert dst.used_tokens == 768


def test_cache_clear_drops_everything():
    pc = PrefixCache(0, capacity_tokens=1000)
    pc.insert(("sys", "a"), (512, 256))
    pc.clear()
    assert len(pc) == 0 and pc.used_tokens == 0


# ---------------------------------------------------------------------------
# TenantRegistry
# ---------------------------------------------------------------------------

def test_registry_lookup_and_default_fallback():
    reg = TenantRegistry([TenantSpec("vip", priority=2, weight=2.0),
                          TenantSpec("bg", priority=0, weight=0.5)])
    assert reg.priority("vip") == 2 and reg.weight("bg") == 0.5
    assert reg.priority("unknown") == 0 and reg.weight("unknown") == 1.0
    assert reg.names() == ("vip", "bg")
    assert reg.preempt


def test_registry_admission_ledger():
    reg = TenantRegistry([TenantSpec("vip")])
    reg.note_admit("vip")
    reg.note_admit("vip")
    reg.note_admit("stray")
    assert reg.admitted() == {"vip": 2, "stray": 1}
    reg.admitted()["vip"] = 99                     # copies don't leak back
    assert reg.admitted()["vip"] == 2


# ---------------------------------------------------------------------------
# per-tenant goodput attribution
# ---------------------------------------------------------------------------

def _rec(rid, tenant, good=True):
    r = RequestRecord(rid, arrival=0.0, input_tokens=100, output_tokens=10,
                      ttft_slo=1.0, tpot_slo=1.0, tenant=tenant)
    r.prefill_done = 0.5 if good else 2.0
    r.finish = r.prefill_done + 0.1
    r.energy_j = 50.0
    return r


def test_summarize_attributes_per_tenant():
    recs = [_rec(0, "vip"), _rec(1, "vip", good=False), _rec(2, "bg")]
    s = summarize(recs, duration_s=10.0, avg_provisioned_w=1000.0)
    assert set(s.per_tenant) == {"bg", "vip"}
    vip = s.per_tenant["vip"]
    assert vip["n_total"] == 2 and vip["n_good"] == 1
    assert vip["slo_attainment"] == 0.5
    assert vip["total_energy_j"] == 100.0
    assert s.per_tenant["bg"]["energy_per_good_token_j"] == 5.0
    assert "vip" in s.row() and "bg" in s.row()


def test_summarize_default_only_stream_has_no_tenant_section():
    recs = [_rec(0, "default"), _rec(1, "default")]
    s = summarize(recs, duration_s=10.0, avg_provisioned_w=1000.0)
    assert s.per_tenant == {}                      # pre-tenancy artifacts
    assert "default" not in s.row()


# ---------------------------------------------------------------------------
# end to end on one node (sanitized): preemption and prefix reuse
# ---------------------------------------------------------------------------

def test_priority_preemption_evicts_lower_priority_decode():
    # 2 decode slots per GPU force saturation; vip arrivals then preempt
    gpu = dataclasses.replace(MI300X, max_active_decode=2)
    reg = TenantRegistry([TenantSpec("vip", priority=2),
                          TenantSpec("batch", priority=0)])
    wl = Workload(
        Workload.uniform(24, qps=40.0, in_tokens=1024, out_tokens=384,
                         seed=0, tenant="batch").entries
        + [(e[0] + 4.0,) + tuple(e[1:]) for e in
           Workload.uniform(8, qps=20.0, in_tokens=1024, out_tokens=64,
                            seed=1, tenant="vip").entries])
    sim = NodeSimulator(CFG, policy_4p4d(600), gpu=gpu, sanitize=True,
                        tenancy=reg)
    s = sim.run(wl)
    assert sim.preempt_trace, "saturated decode never preempted"
    # preempted work is requeued, not dropped: everything still finishes
    assert s.n_finished == s.n_total == 32
    assert set(s.per_tenant) == {"batch", "vip"}
    assert sim.loop.sanitizer is not None and sim.loop.sanitizer.checks > 0


def test_preemption_respects_registry_switch():
    gpu = dataclasses.replace(MI300X, max_active_decode=2)
    reg = TenantRegistry([TenantSpec("vip", priority=2),
                          TenantSpec("batch", priority=0)], preempt=False)
    wl = Workload(
        Workload.uniform(24, qps=40.0, in_tokens=1024, out_tokens=384,
                         seed=0, tenant="batch").entries
        + [(e[0] + 4.0,) + tuple(e[1:]) for e in
           Workload.uniform(8, qps=20.0, in_tokens=1024, out_tokens=64,
                            seed=1, tenant="vip").entries])
    sim = NodeSimulator(CFG, policy_4p4d(600), gpu=gpu, sanitize=True,
                        tenancy=reg)
    s = sim.run(wl)
    assert sim.preempt_trace == []                 # ablation arm: no evictions
    assert s.n_finished == s.n_total


def test_prefix_cache_shortens_session_prefill():
    wl = Workload.sessions(12, turns=4, qps=2.0, tenant="agent", seed=3)
    cold = NodeSimulator(CFG, policy_4p4d(600), sanitize=True)
    s_cold = cold.run(Workload(list(wl.entries)))
    warm = NodeSimulator(CFG, policy_4p4d(600), sanitize=True,
                         cache_cfg=PrefixCacheConfig())
    s_warm = warm.run(Workload(list(wl.entries)))
    assert cold.prefix_hit_tokens == 0
    assert warm.prefix_hit_tokens > 0
    assert warm.prefix_cache.hits > 0
    # reuse can only help: same stream, strictly less prefill work
    assert s_warm.p90_ttft <= s_cold.p90_ttft + 1e-9
    assert s_warm.total_energy_j <= s_cold.total_energy_j + 1e-6
