"""Tests for core/autoscale.py: SignalTrace semantics, forecaster edge
cases (empty window, constant load, short traces, timestamp misalignment),
tariff cost/carbon attribution in the goodput summary, the cost router
policy, and the autoscaler's end-to-end decision behaviour."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.autoscale import (ArrivalForecaster, AutoscaleConfig,
                                  J_PER_KWH, PredictiveAutoscaler,
                                  SignalTrace)
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import ControllerConfig, policy_4p4d
from repro.core.fleet import FleetConfig, FleetManager
from repro.core.goodput import RequestRecord, summarize
from repro.core.simulator import Workload

CFG = get_config("llama31_8b")


def ctrl(**kw):
    return dataclasses.replace(ControllerConfig(), allow_power=True,
                               ttft_slo=2.0, **kw)


# ---------------------------------------------------------------------------
# SignalTrace
# ---------------------------------------------------------------------------

def test_signal_trace_piecewise_and_edge_clamp():
    tr = SignalTrace([10.0, 20.0, 30.0], [0.1, 0.3, 0.2])
    assert tr.value_at(-5.0) == 0.1      # before first knot: clamp left
    assert tr.value_at(10.0) == 0.1
    assert tr.value_at(19.999) == 0.1
    assert tr.value_at(20.0) == 0.3
    assert tr.value_at(25.0) == 0.3
    assert tr.value_at(1e9) == 0.2       # past last knot: clamp right
    np.testing.assert_allclose(
        tr.values_at(np.array([0.0, 15.0, 22.0, 99.0])),
        [0.1, 0.1, 0.3, 0.2])


def test_signal_trace_constant_and_mean():
    flat = SignalTrace.constant(0.25)
    assert flat.value_at(0.0) == flat.value_at(1e6) == 0.25
    tr = SignalTrace([0.0, 10.0], [1.0, 3.0])
    # [5, 15]: 5 s at 1.0, 5 s at 3.0
    assert tr.mean_over(5.0, 15.0) == pytest.approx(2.0)
    assert tr.mean_over(7.0, 7.0) == 1.0    # degenerate span -> point value


def test_signal_trace_rejects_descending_times():
    with pytest.raises(AssertionError):
        SignalTrace([5.0, 1.0], [0.1, 0.2])
    with pytest.raises(AssertionError):
        SignalTrace([], [])


def test_signal_trace_shorter_than_horizon_degrades_to_edges():
    """A price trace covering less than the simulated day must hold its
    edge values rather than raise — arrival timestamps far outside the
    trace's span are legal by construction."""
    tr = SignalTrace([0.0, 5.0], [0.10, 0.30], name="price", units="$/kWh")
    ts = np.array([-100.0, 2.0, 7.0, 3600.0, 86400.0])
    np.testing.assert_allclose(tr.values_at(ts), [0.1, 0.1, 0.3, 0.3, 0.3])


# ---------------------------------------------------------------------------
# ArrivalForecaster edge cases
# ---------------------------------------------------------------------------

def test_forecaster_empty_window():
    f = ArrivalForecaster(bucket_s=2.0, window_s=10.0)
    assert not f.has_data
    assert f.closed_buckets() == 0
    assert f.rate_now(100.0) == 0.0
    assert f.forecast(100.0, 10.0) == 0.0
    assert f.mean_input_tokens(default=1234.0) == 1234.0


def test_forecaster_constant_load_converges():
    f = ArrivalForecaster(bucket_s=1.0, window_s=10.0)
    for i in range(100):                  # 5 req/s, uniform
        f.observe(i * 0.2, in_tokens=2048)
    assert f.has_data
    assert f.rate_now(20.0) == pytest.approx(5.0, rel=0.05)
    # constant load: no trend, any horizon forecasts the same rate
    assert f.forecast(20.0, 30.0) == pytest.approx(5.0, rel=0.05)
    assert f.mean_input_tokens() == 2048.0


def test_forecaster_seasonal_needs_full_season():
    f = ArrivalForecaster(bucket_s=1.0, window_s=5.0, season_s=20.0)
    for i in range(40):                   # 2 req/s over [0, 20)
        f.observe(i * 0.5)
    # target window [22, 32) maps one season back to [2, 12): observed
    assert f._seasonal_rate(22.0, 32.0) == pytest.approx(2.0)
    # target [5, 10) maps to [-15, -10): predates history
    assert f._seasonal_rate(5.0, 10.0) is None


def test_forecaster_seasonal_is_peak_seeking():
    """The seasonal term reports the PEAK bucket rate across the forecast
    window: a ramp starting mid-horizon must not be diluted by the quiet
    buckets before it."""
    f = ArrivalForecaster(bucket_s=1.0, window_s=5.0, season_s=30.0)
    t = 0.0
    while t < 20.0:                       # trough: 2 req/s
        f.observe(t)
        t += 0.5
    while t < 30.0:                       # peak: 20 req/s
        f.observe(t)
        t += 0.05
    # day 2, just before the ramp: horizon straddles trough end + peak start
    rate = f.forecast(45.0, 10.0)
    assert rate == pytest.approx(20.0, rel=0.1), \
        "forecast must see the ramp coming, not average it away"


def test_forecaster_window_prunes_old_buckets():
    f = ArrivalForecaster(bucket_s=1.0, window_s=5.0)
    for i in range(20):                   # 1 req/s over [0, 20)
        f.observe(float(i))
    f.observe(100.0)                      # long gap, then one arrival
    f._roll(102)
    assert f.closed_buckets() <= 6        # window is 5 buckets + current


def test_forecaster_misaligned_timestamps():
    """Arrivals at irrational offsets and ticks at times that never
    coincide with bucket edges must still bucket consistently (the trace /
    arrival misalignment case)."""
    f = ArrivalForecaster(bucket_s=2.0, window_s=20.0)
    for i in range(60):
        f.observe(0.1234 + i * 0.3333)
    r = f.rate_now(0.1234 + 60 * 0.3333)
    assert r == pytest.approx(3.0, rel=0.15)


# ---------------------------------------------------------------------------
# tariff attribution in the goodput summary
# ---------------------------------------------------------------------------

def _rec(rid, arrival, fin, energy, out=100, good=True):
    slo = 10.0 if good else 1e-9
    return RequestRecord(rid=rid, arrival=arrival, input_tokens=100,
                         output_tokens=out, prefill_done=arrival + 0.1,
                         finish=fin, ttft_slo=slo, tpot_slo=slo,
                         energy_j=energy)


def test_summary_cost_and_carbon_attribution():
    price = SignalTrace([0.0, 10.0], [0.10, 0.50])
    carbon = SignalTrace([0.0], [400.0])
    recs = [_rec(0, 1.0, 5.0, J_PER_KWH),       # finishes at $0.10/kWh
            _rec(1, 9.0, 15.0, 2 * J_PER_KWH)]  # finishes at $0.50/kWh
    s = summarize(recs, 20.0, 1000.0, price_trace=price,
                  carbon_trace=carbon)
    assert s.total_cost_usd == pytest.approx(1 * 0.10 + 2 * 0.50)
    assert s.total_carbon_g == pytest.approx(3 * 400.0)
    good_tokens = 200.0
    assert s.cost_per_good_token_usd == pytest.approx(1.10 / good_tokens)
    assert s.carbon_per_good_token_g == pytest.approx(1200.0 / good_tokens)


def test_summary_unfinished_request_priced_at_arrival():
    price = SignalTrace([0.0, 10.0], [0.10, 0.50])
    lost = RequestRecord(rid=0, arrival=2.0, input_tokens=10,
                         output_tokens=10, energy_j=J_PER_KWH)
    done = _rec(1, 12.0, 15.0, J_PER_KWH)
    s = summarize([lost, done], 20.0, 1000.0, price_trace=price)
    # lost request's partial work priced at its arrival-time tariff (0.10)
    assert s.total_cost_usd == pytest.approx(0.10 + 0.50)


def test_summary_without_traces_is_unchanged():
    recs = [_rec(0, 1.0, 5.0, 123.0)]
    s = summarize(recs, 10.0, 500.0)
    assert s.total_cost_usd == 0.0
    assert s.cost_per_good_token_usd == 0.0
    assert s.total_carbon_g == 0.0
    assert "$" not in s.row() and "gCO2" not in s.row()


def test_summary_no_good_tokens_yields_zero_rates():
    recs = [_rec(0, 1.0, 5.0, 50.0, good=False)]
    s = summarize(recs, 10.0, 500.0, price_trace=SignalTrace.constant(0.2),
                  carbon_trace=SignalTrace.constant(300.0))
    assert s.total_cost_usd > 0.0           # joules were still paid for
    assert s.cost_per_good_token_usd == 0.0  # but nothing good to amortize
    assert s.carbon_per_good_token_g == 0.0


# ---------------------------------------------------------------------------
# cost router policy
# ---------------------------------------------------------------------------

def _mini_cluster(router_policy="cost", n=2):
    return ClusterSimulator(CFG, policy_4p4d(500), n, node_budget_w=4000.0,
                            ctrl_cfg=ctrl(),
                            cluster_cfg=ClusterConfig(allow_shift=False),
                            seed=0, router_policy=router_policy)


def test_cost_router_prefers_cheap_node():
    cs = _mini_cluster()
    # node 0 pays 5x the tariff of node 1
    cs.router.price_fn = lambda nid, now: 0.5 if nid == 0 else 0.1
    wl = Workload.uniform(20, qps=2.0, in_tokens=1024, out_tokens=64,
                          seed=1, ttft_slo=2.0)
    cs.run(wl)
    picks = [nid for _, nid in cs.router.trace]
    # light load: every request has headroom everywhere -> cheap node wins
    assert picks.count(1) > picks.count(0) * 3


def test_cost_router_falls_back_to_load_when_saturated():
    """When no node has TTFT headroom the cost policy must load-balance,
    not keep piling onto whichever node is cheapest."""
    cs = _mini_cluster()
    cs.router.price_fn = lambda nid, now: 0.5 if nid == 0 else 0.1
    wl = Workload.uniform(120, qps=30.0, in_tokens=4096, out_tokens=64,
                          seed=1, ttft_slo=0.5)
    cs.run(wl)
    picks = [nid for _, nid in cs.router.trace]
    assert picks.count(0) > len(picks) * 0.2, \
        "the expensive node must still absorb work once the cheap one " \
        "runs out of latency headroom"


def test_cost_router_uniform_price_degrades_to_joules():
    a = _mini_cluster(router_policy="cost")
    a.router.price_fn = lambda nid, now: 0.2
    b = _mini_cluster(router_policy="joules")
    wl = Workload.uniform(30, qps=4.0, in_tokens=2048, out_tokens=64,
                          seed=2, ttft_slo=2.0)
    sa = a.run(wl)
    wl2 = Workload.uniform(30, qps=4.0, in_tokens=2048, out_tokens=64,
                           seed=2, ttft_slo=2.0)
    sb = b.run(wl2)
    # identical light-load scenario: uniform tariff cannot reorder nodes
    # that joules ranks, so attainment and energy must agree
    assert sa.slo_attainment == sb.slo_attainment
    assert sa.total_energy_j == pytest.approx(sb.total_energy_j, rel=1e-6)


# ---------------------------------------------------------------------------
# PredictiveAutoscaler end-to-end decisions
# ---------------------------------------------------------------------------

def _fleet(mode, n=3, standby=(2,), **cfg_kw):
    cs = ClusterSimulator(CFG, policy_4p4d(500), n, node_budget_w=4000.0,
                          ctrl_cfg=ctrl(),
                          cluster_cfg=ClusterConfig(allow_shift=True),
                          seed=7, router_policy="cost")
    fm = FleetManager(cs, FleetConfig(elastic=True), standby=standby)
    asc = PredictiveAutoscaler(
        fm, AutoscaleConfig(mode=mode, period_s=2.0, window_s=12.0,
                            holdoff_s=6.0, **cfg_kw),
        price_trace=SignalTrace.constant(0.2, name="price", units="$/kWh"),
        carbon_trace=SignalTrace.constant(350.0))
    asc.start()
    return cs, fm, asc


def test_autoscaler_joins_standby_on_ramp():
    cs, fm, asc = _fleet("reactive")
    ramp = Workload.phased_mix([
        Workload.uniform(24, qps=3.0, in_tokens=4096, out_tokens=128,
                         seed=1, ttft_slo=2.0),
        Workload.uniform(240, qps=20.0, in_tokens=4096, out_tokens=128,
                         seed=2, ttft_slo=2.0)])
    cs.run(ramp)
    joins = [d for d in asc.decision_trace if d[1] == "join"]
    assert joins, "a 6x ramp past 2-node capacity must power standby on"
    # the standby node actually came up (it may consolidate away again
    # once the tail of the queue drains and demand decays)
    assert ("join_done", 2) in [(k, n) for _, k, n in fm.churn_trace]
    cs.assert_facility_invariant()


def test_autoscaler_consolidates_at_trough():
    cs, fm, asc = _fleet("reactive", n=3, standby=(), min_nodes=1)
    lull = Workload.uniform(60, qps=2.0, in_tokens=2048, out_tokens=64,
                            seed=3, ttft_slo=2.0)
    cs.run(lull)
    leaves = [d for d in asc.decision_trace if d[1] == "leave"]
    assert leaves, "3 nodes at 2 req/s must consolidate"
    assert sum(cs.active) < 3
    cs.assert_facility_invariant()


def test_autoscaler_never_acts_without_observations():
    cs, fm, asc = _fleet("reactive")
    # tick the loop with no workload at all: push a sentinel end event
    cs.loop.push(30.0, lambda k, p=None: None, "noop")
    cs.loop.run(until=lambda: not cs.loop.heap)
    assert asc.decision_trace == [], \
        "an empty arrival window must never trigger membership changes"


def test_autoscaler_static_mode_only_observes():
    cs, fm, asc = _fleet("static")
    wl = Workload.uniform(80, qps=10.0, in_tokens=4096, out_tokens=128,
                          seed=5, ttft_slo=2.0)
    s = cs.run(wl)
    assert asc.decision_trace == []
    assert asc.signal_trace, "static mode still records its signals"
    # tariff attribution flows through the summary even in static mode
    assert s.total_cost_usd > 0.0
    assert s.total_carbon_g > 0.0


def test_autoscaler_rejects_unknown_mode():
    cs = _mini_cluster()
    fm = FleetManager(cs, FleetConfig(elastic=True))
    with pytest.raises(AssertionError):
        PredictiveAutoscaler(fm, AutoscaleConfig(mode="clairvoyant"))
