"""Prefill+decode must reproduce teacher-forced logits for every family,
including sliding-window attention, dropless-MoE, recurrent state handoff."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import LM, make_demo_batch

CASES = [
    ("qwen1_5_4b", None, False),
    ("starcoder2_15b", None, False),
    ("phi3_5_moe", None, True),
    ("llama4_maverick", None, True),
    ("xlstm_350m", None, False),
    ("recurrentgemma_2b", None, False),
    ("whisper_large_v3", None, False),
    ("chameleon_34b", 8, False),
    ("granite_3_8b", 8, False),
]


@pytest.mark.parametrize("arch,window,dropless", CASES)
def test_prefill_decode_matches_teacher_forcing(arch, window, dropless):
    cfg = get_config(arch).reduced()
    if dropless:
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.n_experts) / max(cfg.top_k, 1))
    lm = LM(cfg)
    key = jax.random.key(1)
    params = lm.init(key)
    B, S, P = 2, 24, 16
    batch = make_demo_batch(cfg, B, S, key)
    full, _ = lm.forward_train(params, batch, remat=False, window=window)
    cache = lm.init_cache(B, S + 4, dtype=jnp.float32, window=window)
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :P]
    lg, cache = lm.prefill(params, pb, cache, window=window)
    errs = [float(jnp.max(jnp.abs(lg - full[:, P - 1])))]
    for t in range(P, S):
        lg, cache = lm.decode_step(params, batch["tokens"][:, t], cache,
                                   window=window)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert max(errs) < 2e-3, (arch, errs)


def test_vector_pos_decode_matches_scalar():
    """Per-slot positions (continuous batching) == scalar-pos decode."""
    cfg = get_config("qwen1_5_4b").reduced()
    lm = LM(cfg)
    key = jax.random.key(3)
    params = lm.init(key)
    B, P = 2, 12
    batch = make_demo_batch(cfg, B, P, key)
    cache = lm.init_cache(B, 24, dtype=jnp.float32)
    lg_s, cache_s = lm.prefill(params, batch, cache)
    tok = jnp.argmax(lg_s, -1)
    lg1, _ = lm.decode_step(params, tok, cache_s)
    cache_v = dict(cache_s)
    cache_v["pos"] = jnp.full((B,), P, jnp.int32)
    lg2, _ = lm.decode_step(params, tok, cache_v)
    assert float(jnp.max(jnp.abs(lg1 - lg2))) < 1e-5
