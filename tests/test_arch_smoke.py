"""Per-assigned-architecture smoke tests: reduced variant of each family,
one forward + one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import LM, make_demo_batch
from repro.training.optimizer import AdamWConfig, apply_updates, init_state

B, S = 2, 24


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch, key):
    cfg = get_config(arch).reduced()
    lm = LM(cfg)
    params = lm.init(key)
    batch = make_demo_batch(cfg, B, S, key)

    logits, aux = lm.forward_train(params, batch, remat=False)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not jnp.isnan(logits).any()
    assert jnp.isfinite(aux)

    opt_cfg = AdamWConfig(total_steps=10, warmup_steps=1)
    opt_state = init_state(opt_cfg, params)

    def loss_fn(p):
        return lm.loss(p, batch, remat=False)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    new_params, _, metrics = apply_updates(opt_cfg, params, grads, opt_state)
    assert jnp.isfinite(metrics["grad_norm"])
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a - b))),
                     params, new_params))
    assert moved > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_path_shapes(arch, key):
    cfg = get_config(arch).reduced()
    lm = LM(cfg)
    params = lm.init(key)
    batch = make_demo_batch(cfg, B, 16, key)
    cache = lm.init_cache(B, 32, dtype=jnp.float32)
    logits, cache = lm.prefill(params, batch, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert not jnp.isnan(logits).any()
    logits, cache = lm.decode_step(params, jnp.argmax(logits, -1), cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert not jnp.isnan(logits).any()
