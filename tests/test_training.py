"""Training substrate: loss goes down, checkpoint roundtrip, data pipeline."""
import os

import jax
import numpy as np

from repro.configs import get_config
from repro.training import checkpoint as ckpt
from repro.training.data import TokenStream
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def test_loss_decreases():
    cfg = get_config("qwen1_5_4b").reduced()
    opt = AdamWConfig(lr=3e-3, grad_clip=10.0, total_steps=40,
                      warmup_steps=4, weight_decay=0.0)
    _, hist = train(cfg, steps=40, batch_size=4, seq_len=64, log_every=0,
                    remat=False, opt_cfg=opt)
    assert min(hist[-10:]) < hist[0] - 0.15


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("xlstm_350m").reduced()
    from repro.models import LM
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    path = os.path.join(tmp_path, "ck.npz")
    ckpt.save(path, {"params": params}, step=7)
    restored, step = ckpt.restore(path, {"params": params})
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, restored["params"])


def test_data_pipeline_shapes_and_determinism():
    cfg = get_config("qwen1_5_4b").reduced()
    a = TokenStream(cfg, seed=3).batch(4, 32)
    b = TokenStream(cfg, seed=3).batch(4, 32)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    assert a["tokens"].max() < cfg.vocab_size


def test_enc_dec_batch_has_frontend_stub():
    cfg = get_config("whisper_large_v3").reduced()
    b = TokenStream(cfg, seed=0).batch(2, 16)
    assert b["enc_feats"].shape == (2, cfg.encoder_seq, cfg.d_model)
