"""Real-compute disaggregated engine: KV handoff through the ring buffer,
continuous batching with per-slot positions, exact token-level consistency."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.controller import ControllerConfig
from repro.models import LM
from repro.serving.engine import DisaggEngine


@pytest.mark.parametrize("arch", ["qwen1_5_4b", "xlstm_350m",
                                  "recurrentgemma_2b", "whisper_large_v3"])
def test_engine_serves_all_requests(arch, rng):
    cfg = get_config(arch).reduced()
    eng = DisaggEngine(cfg, n_prefill=1, n_decode=1, max_len=80,
                       decode_slots=3)
    for _ in range(5):
        eng.submit(rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                   8, 0.0)
    s = eng.run()
    assert s.n_finished == 5
    assert all(len(r.generated) == 8 for r in eng.finished)


def test_engine_tokens_match_single_request_decode(rng):
    """Continuous batching (mixed positions, slot insertion) must produce
    exactly the tokens of an isolated prefill+decode."""
    cfg = get_config("qwen1_5_4b").reduced()
    eng = DisaggEngine(cfg, n_prefill=1, n_decode=1, max_len=64,
                       decode_slots=3, seed=7)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (12, 20, 17)]
    for p in prompts:
        eng.submit(p, 8, 0.0)
    eng.run()
    lm = LM(cfg)
    for req in eng.finished:
        cache = lm.init_cache(1, 64, dtype=jnp.float32)
        lg, cache = lm.prefill(eng.params,
                               {"tokens": jnp.asarray(req.tokens)[None]},
                               cache)
        toks = [int(jnp.argmax(lg[0]))]
        for _ in range(len(req.generated) - 1):
            lg, cache = lm.decode_step(eng.params, jnp.asarray([toks[-1]]),
                                       cache)
            toks.append(int(jnp.argmax(lg[0])))
        assert toks == req.generated


def test_engine_with_controller_respects_budget(rng):
    cfg = get_config("qwen1_5_4b").reduced()
    ctrl = ControllerConfig(ttft_slo=0.01, tpot_slo=0.001, cooldown_s=0.1,
                            power_cooldown_s=0.02, allow_power=True,
                            allow_gpu=True)
    eng = DisaggEngine(cfg, n_prefill=2, n_decode=2, max_len=64,
                       decode_slots=3, ctrl_cfg=ctrl)
    for _ in range(8):
        eng.submit(rng.integers(0, cfg.vocab_size, 20).astype(np.int32),
                   6, 0.0)
    eng.run()
    assert sum(eng.pm.effective) <= eng.pm.budget + 1e-6


def test_ring_backpressure(rng):
    from repro.serving.ring import KVRing
    ring = KVRing(2)
    assert ring.try_put("a") is not None
    assert ring.try_put("b") is not None
    assert ring.try_put("c") is None       # full -> backpressure
    assert ring.try_pull() == "a"          # pull frees a slot, FIFO order
    assert ring.try_put("c") is not None
