"""Golden equivalence of the macro-stepped simulator core.

``fidelity="macro"`` coalesces runs of decode iterations into single events;
these tests pin the contract that it is *observationally identical* to the
per-iteration path (``fidelity="iter"``): same per-request TTFT/TPOT record
timestamps to the last bit, same goodput summaries, same controller/
coordinator traces — across all four paper policies, including mid-drain
DynGPU flips, cluster budget shifting, and heterogeneous cluster role
flips. Each pair also asserts the macro arm dispatched far fewer events, so
the test cannot pass vacuously with macro-stepping disabled."""
import dataclasses

import numpy as np

from repro.configs import get_config
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import ControllerConfig, StaticPolicy, policy_4p4d
from repro.core.costmodel import H100, MI300X
from repro.core.fleet import FleetConfig, FleetManager
from repro.core.simulator import MetricWindow, NodeSimulator, Workload

CFG = get_config("llama31_8b")


def ctrl(power=True, gpu=False, **kw):
    return dataclasses.replace(ControllerConfig(), allow_power=power,
                               allow_gpu=gpu, **kw)


def assert_identical(run):
    """Run the same scenario under both fidelities; records and summaries
    must match exactly (==, not approx: the macro path must reproduce the
    same IEEE floats)."""
    sims, summaries, events = {}, {}, {}
    for fid in ("iter", "macro"):
        sim, s = run(fid)
        sims[fid] = sim
        summaries[fid] = s
        events[fid] = sim.loop.dispatched
    rec_i = [(r.rid, r.arrival, r.prefill_done, r.finish, r.energy_j)
             for r in sims["iter"].records]
    rec_m = [(r.rid, r.arrival, r.prefill_done, r.finish, r.energy_j)
             for r in sims["macro"].records]
    assert rec_i == rec_m
    assert dataclasses.asdict(summaries["iter"]) == \
        dataclasses.asdict(summaries["macro"])
    # macro-stepping must actually engage: coalescing decode iterations
    # must visibly shrink the event count (prefill-heavy scenarios reduce
    # less — most of their events are not decode iterations)
    assert events["macro"] < events["iter"] * 0.8, events
    return sims["iter"], sims["macro"]


# ---------------------------------------------------------------------------
# single node: all four paper policies
# ---------------------------------------------------------------------------

def node_run(fid, *, wl_f, c=None, policy=None, coalesced=False):
    sim = NodeSimulator(CFG, policy or policy_4p4d(600), ctrl_cfg=c,
                        coalesced=coalesced, seed=0, fidelity=fid)
    s = sim.run(wl_f())
    return sim, s


def test_static_longbench_identical():
    """Fig5-shaped: static policy under long-tailed prefill traffic."""
    assert_identical(lambda fid: node_run(
        fid, wl_f=lambda: Workload.longbench_like(150, qps=8.0, seed=2)))


def test_dynpower_identical():
    assert_identical(lambda fid: node_run(
        fid, c=ctrl(power=True, gpu=False),
        wl_f=lambda: Workload.sonnet_phases(6.5, seed=5, n1=120, n2=120)))


def test_dyngpu_identical_with_mid_drain_flip():
    """Fig8-shaped: DynGPU only — the phase shift forces role flips, so the
    macro path must handle drain migrations (batch moved off a mid-plan
    GPU) exactly."""
    it, ma = assert_identical(lambda fid: node_run(
        fid, c=ctrl(power=False, gpu=True),
        wl_f=lambda: Workload.sonnet_phases(6.5, seed=5, n1=150, n2=150)))
    kinds = [k for _, k, _ in it.ctrl.trace]
    assert "gpu" in kinds, "scenario must actually exercise a role flip"
    assert it.ctrl.trace == ma.ctrl.trace


def test_dynpower_dyngpu_identical():
    """Both knobs (the paper's full RAPID controller): power shifts with
    in-flight cap enforcement AND GPU moves interleaving with macro plans."""
    it, ma = assert_identical(lambda fid: node_run(
        fid, c=ctrl(power=True, gpu=True),
        wl_f=lambda: Workload.sonnet_phases(6.5, seed=5, n1=150, n2=150)))
    assert it.ctrl.trace == ma.ctrl.trace
    assert len(it.ctrl.trace) > 0


def test_coalesced_identical():
    """Chunked-prefill baseline keeps its per-iteration path untouched."""
    sims, summaries = {}, {}
    for fid in ("iter", "macro"):
        sim, s = node_run(
            fid, policy=StaticPolicy(4, 4, 600, 600, "coal"), coalesced=True,
            wl_f=lambda: Workload.longbench_like(100, qps=9.0, seed=4))
        sims[fid], summaries[fid] = sim, s
    assert dataclasses.asdict(summaries["iter"]) == \
        dataclasses.asdict(summaries["macro"])


# ---------------------------------------------------------------------------
# cluster: budget shifts + coordinator role flips (fig9/fig10-shaped)
# ---------------------------------------------------------------------------

def test_cluster_skew_shifting_identical():
    """Fig9 skew scenario: watts cross node boundaries mid-run; in-flight
    budget shrinks and cap raises must cut macro plans identically."""
    def run(fid):
        cs = ClusterSimulator(CFG, policy_4p4d(500), 2, node_budget_w=4000.0,
                              ctrl_cfg=ctrl(ttft_slo=2.0),
                              cluster_cfg=ClusterConfig(allow_shift=True),
                              seed=7, fidelity=fid)
        pinned = {0: Workload.uniform(80, qps=4.0, in_tokens=8192,
                                      out_tokens=128, seed=11, ttft_slo=2.0),
                  1: Workload.uniform(80, qps=4.0, in_tokens=500,
                                      out_tokens=500, seed=12,
                                      tpot_slo=0.020)}
        s = cs.run(pinned=pinned)
        return cs, s

    res = {}
    for fid in ("iter", "macro"):
        cs, s = run(fid)
        res[fid] = (cs, s,
                    [(r.rid, r.arrival, r.prefill_done, r.finish)
                     for r in cs.records])
    assert res["iter"][2] == res["macro"][2]
    assert dataclasses.asdict(res["iter"][1]) == \
        dataclasses.asdict(res["macro"][1])
    assert res["iter"][0].shift_trace == res["macro"][0].shift_trace
    assert len(res["iter"][0].shift_trace) > 0
    assert res["macro"][0].loop.dispatched < \
        res["iter"][0].loop.dispatched / 2


def test_cluster_hetero_dyngpu_flip_identical():
    """Fig10-shaped: heterogeneous nodes, coordinator MoveGPU — drains on a
    shared loop with macro plans in flight on both nodes."""
    def run(fid):
        cs = ClusterSimulator(
            CFG, policy_4p4d(500), 2, node_budget_w=4000.0,
            ctrl_cfg=ctrl(ttft_slo=2.0),
            cluster_cfg=ClusterConfig(allow_shift=True, allow_gpu_move=True),
            gpu_specs=[MI300X, H100], seed=5, fidelity=fid)
        routed = Workload.uniform(90, qps=8.0, in_tokens=8192,
                                  out_tokens=128, seed=5, ttft_slo=2.0)
        pinned = {0: Workload.uniform(45, qps=2.0, in_tokens=500,
                                      out_tokens=500, seed=6,
                                      tpot_slo=0.030)}
        s = cs.run(routed, pinned=pinned)
        return cs, s

    res = {}
    for fid in ("iter", "macro"):
        cs, s = run(fid)
        res[fid] = (cs, s,
                    [(r.rid, r.arrival, r.prefill_done, r.finish)
                     for r in cs.records])
    assert res["iter"][2] == res["macro"][2]
    assert dataclasses.asdict(res["iter"][1]) == \
        dataclasses.asdict(res["macro"][1])
    assert res["iter"][0].flip_trace == res["macro"][0].flip_trace
    assert res["iter"][0].flip_done_trace == res["macro"][0].flip_done_trace
    assert len(res["iter"][0].flip_trace) > 0, \
        "scenario must exercise a coordinator-initiated mid-drain flip"
    # routing decisions (cross-node reads against macro-stepped state)
    assert res["iter"][0].router.trace == res["macro"][0].router.trace


def test_fleet_churn_and_migration_identical():
    """Elastic-fleet golden run: a node leave mid-run (cross-node KV
    migration of mid-decode batches), an abrupt failure (state loss +
    requeue), and a standby-style rejoin with facility-level power
    redistribution — all while the coordinator shifts budgets. Macro plans
    must truncate at every churn/migration boundary exactly where the
    per-iteration path re-reads the world: per-request records (including
    the accumulated energy_j), goodput summaries, and the fleet's own churn
    and migration traces must match to the last bit."""
    def run(fid):
        cs = ClusterSimulator(
            CFG, policy_4p4d(500), 3, node_budget_w=4000.0,
            ctrl_cfg=ctrl(ttft_slo=2.0),
            cluster_cfg=ClusterConfig(allow_shift=True),
            seed=3, fidelity=fid)
        fm = FleetManager(cs, FleetConfig(elastic=True))
        fm.schedule_leave(8.0, 2)      # node 2 drains: mid-decode migration
        fm.schedule_fail(15.0, 1)      # node 1 dies: requeue from scratch
        fm.schedule_join(22.0, 2)      # node 2 returns: facility re-level
        wl = Workload.uniform(260, qps=8.0, in_tokens=4096, out_tokens=256,
                              seed=4, ttft_slo=2.0)
        s = cs.run(wl)
        return cs, fm, s

    res = {}
    for fid in ("iter", "macro"):
        cs, fm, s = run(fid)
        res[fid] = (cs, fm, s,
                    [(r.rid, r.arrival, r.prefill_done, r.finish, r.energy_j)
                     for r in cs.records])
    it, ma = res["iter"], res["macro"]
    assert it[3] == ma[3]
    assert dataclasses.asdict(it[2]) == dataclasses.asdict(ma[2])
    assert it[1].churn_trace == ma[1].churn_trace
    assert it[1].migration_trace == ma[1].migration_trace
    assert it[1].requeue_trace == ma[1].requeue_trace
    assert it[0].shift_trace == ma[0].shift_trace
    assert it[0].router.trace == ma[0].router.trace
    # the scenario must actually exercise every churn path
    kinds = [k for _, k, _ in it[1].churn_trace]
    assert kinds == ["leave", "leave_done", "fail", "join", "join_done"]
    assert len(it[1].migration_trace) > 0, "leave must migrate live KV"
    assert len(it[1].requeue_trace) > 0, "failure must requeue lost work"
    assert all(np.isfinite(e) and e > 0 for *_, e in it[3])
    assert ma[0].loop.dispatched < it[0].loop.dispatched / 2


def test_tenant_affinity_preemption_identical():
    """Multi-tenant golden run: affinity routing against each node's prefix
    cache, session traffic hitting cached prefixes (discounted prefill
    energy folds), and priority preemption evicting saturated decode
    batches back through the requeue machinery — per-request records
    (including the discounted energy_j), per-tenant summaries, preemption
    traces, prefix hit counters, and routing decisions must all match to
    the last bit between fidelities."""
    from repro.core.prefixcache import PrefixCacheConfig
    from repro.core.tenancy import TenantRegistry, TenantSpec

    def run(fid):
        reg = TenantRegistry([TenantSpec("agent", priority=2, weight=2.0),
                              TenantSpec("batch", priority=0, weight=0.5)])
        cs = ClusterSimulator(
            CFG, policy_4p4d(500), 2, node_budget_w=4000.0,
            ctrl_cfg=ctrl(ttft_slo=2.0),
            cluster_cfg=ClusterConfig(allow_shift=True),
            gpu=dataclasses.replace(MI300X, max_active_decode=2),
            seed=9, fidelity=fid, router_policy="affinity",
            tenancy=reg, cache_cfg=PrefixCacheConfig())
        wl = Workload(
            Workload.uniform(40, qps=14.0, in_tokens=1536, out_tokens=320,
                             seed=21, tenant="batch").entries
            + [(e[0] + 2.0,) + tuple(e[1:]) for e in
               Workload.sessions(10, turns=4, qps=3.0, tenant="agent",
                                 seed=22, out_tokens=64).entries])
        s = cs.run(wl)
        return cs, s

    res = {}
    for fid in ("iter", "macro"):
        cs, s = run(fid)
        res[fid] = (cs, s,
                    [(r.rid, r.arrival, r.prefill_done, r.finish, r.energy_j)
                     for r in cs.records],
                    [nd.preempt_trace for nd in cs.nodes],
                    [nd.prefix_hit_tokens for nd in cs.nodes])
    it, ma = res["iter"], res["macro"]
    assert it[2] == ma[2]
    assert dataclasses.asdict(it[1]) == dataclasses.asdict(ma[1])
    assert it[3] == ma[3]
    assert it[4] == ma[4]
    assert it[0].router.trace == ma[0].router.trace
    # the scenario must actually exercise the subsystem both ways
    assert any(it[3]), "saturated decode must trigger a preemption"
    assert sum(it[4]) > 0, "session traffic must hit the prefix cache"
    assert set(it[1].per_tenant) == {"agent", "batch"}
    # tiny decode batches (2 slots) + preemption truncation leave less to
    # coalesce than the long-batch scenarios' /2 — but macro must engage
    assert ma[0].loop.dispatched < it[0].loop.dispatched * 0.8


def test_autoscaler_active_identical():
    """Golden run with the predictive autoscaler driving membership: its
    decision ticks read cross-node state (capacities, trailing summaries)
    and issue joins/leaves mid-flight, so every tick must land on a fully
    materialized world in macro mode. Decision traces, signal traces,
    per-request records (including energy), tariff-priced summaries, and
    the fleet churn traces must all match to the last bit."""
    from repro.core.autoscale import (AutoscaleConfig, PredictiveAutoscaler,
                                      SignalTrace)

    def run(fid):
        cs = ClusterSimulator(
            CFG, policy_4p4d(500), 3, node_budget_w=4000.0,
            ctrl_cfg=ctrl(ttft_slo=2.0),
            cluster_cfg=ClusterConfig(allow_shift=True),
            seed=3, fidelity=fid, router_policy="cost")
        fm = FleetManager(cs, FleetConfig(elastic=True), standby=(2,))
        asc = PredictiveAutoscaler(
            fm, AutoscaleConfig(mode="reactive", period_s=2.0,
                                window_s=12.0, holdoff_s=6.0),
            price_trace=SignalTrace([0.0, 12.0, 26.0], [0.1, 0.4, 0.1]),
            carbon_trace=SignalTrace([0.0], [380.0]))
        asc.start()
        wl = Workload.phased_mix([
            Workload.uniform(30, qps=3.0, in_tokens=4096, out_tokens=256,
                             seed=4, ttft_slo=2.0),
            Workload.uniform(160, qps=16.0, in_tokens=4096, out_tokens=256,
                             seed=5, ttft_slo=2.0),
            Workload.uniform(30, qps=3.0, in_tokens=4096, out_tokens=256,
                             seed=6, ttft_slo=2.0)])
        s = cs.run(wl)
        return cs, fm, asc, s

    res = {}
    for fid in ("iter", "macro"):
        cs, fm, asc, s = run(fid)
        res[fid] = (cs, fm, asc, s,
                    [(r.rid, r.arrival, r.prefill_done, r.finish, r.energy_j)
                     for r in cs.records])
    it, ma = res["iter"], res["macro"]
    assert it[4] == ma[4]
    assert dataclasses.asdict(it[3]) == dataclasses.asdict(ma[3])
    assert it[2].decision_trace == ma[2].decision_trace
    assert it[2].signal_trace == ma[2].signal_trace
    assert it[1].churn_trace == ma[1].churn_trace
    assert it[1].migration_trace == ma[1].migration_trace
    assert it[0].router.trace == ma[0].router.trace
    # the scenario must actually exercise the decision loop both ways,
    # and the tariff must actually price the records
    kinds = {k for _, k, *_ in it[2].decision_trace}
    assert kinds == {"join", "leave"}, it[2].decision_trace
    assert it[3].total_cost_usd > 0.0 and it[3].total_carbon_g > 0.0
    assert ma[0].loop.dispatched < it[0].loop.dispatched / 2


# ---------------------------------------------------------------------------
# building-block properties the macro path relies on
# ---------------------------------------------------------------------------

def test_cumsum_is_sequential_fold():
    """np.cumsum must reproduce the (t += dt) float chain bit-for-bit —
    the vectorized plan builder depends on accumulate being a left fold."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        k = int(rng.integers(1, 1500))
        t0 = float(rng.uniform(0, 1e4))
        dts = rng.uniform(1e-4, 0.05, k)
        seq, t = [], t0
        for dt in dts.tolist():
            t = t + dt
            seq.append(t)
        acc = np.empty(k + 1)
        acc[0] = t0
        acc[1:] = dts
        assert np.cumsum(acc, out=acc)[1:].tolist() == seq


def test_metric_window_p90_matches_percentile():
    """MetricWindow.p90 == np.percentile(in-window values, 90) exactly,
    for sorted, interleaved, small, large, and tie-heavy windows."""
    rng = np.random.default_rng(1)
    for trial in range(100):
        n = int(rng.integers(1, 800))
        ts = rng.uniform(0, 100, n)
        if trial % 2:
            ts = np.sort(ts)          # the per-iteration path's ordering
        vs = rng.uniform(0, 1, n)
        if trial % 3 == 0:
            vs = np.round(vs, 2)      # force ties
        win = MetricWindow()
        for t, v in zip(ts.tolist(), vs.tolist()):
            win.append(t, v)
        cutoff = float(rng.uniform(-10, 110))
        alive = vs[ts >= cutoff]
        expect = float(np.percentile(alive, 90)) if alive.size else 0.0
        assert win.p90(cutoff) == expect
        # repeated read (memo path) must agree
        assert win.p90(cutoff) == expect


def test_metric_window_eviction_and_growth():
    win = MetricWindow()
    for i in range(10000):
        win.append(float(i), float(i % 7))
    assert len(win) == 10000
    win.p90(9990.0)
    assert len(win) == 10
    assert win.p90(10001.0) == 0.0
    assert len(win) == 0


def test_ctx_sums_stay_consistent():
    """The incremental per-GPU/global context sums must equal a recount
    from the active lists at end of run (guards both fidelities, since the
    per-iteration path uses the same incremental bookkeeping)."""
    for fid in ("iter", "macro"):
        sim = NodeSimulator(CFG, policy_4p4d(600), ctrl_cfg=ctrl(gpu=True),
                            seed=0, fidelity=fid)
        wl = Workload.sonnet_phases(6.5, seed=9, n1=80, n2=80)
        for i, (t, it_, ot, ts, ps) in enumerate(wl.entries):
            from repro.core.goodput import RequestRecord
            from repro.core.simulator import SimRequest
            rec = RequestRecord(i, t, it_, ot, ttft_slo=ts, tpot_slo=ps)
            sim.records.append(rec)
            sim._push(t, "arrival", SimRequest(rec, preregistered=True))
        sim.start()
        # drive partway, then audit mid-flight state after a sync
        for _ in range(3000):
            if not sim.loop.heap:
                break
            sim.loop.step()
        sim.sync()
        total, count = 0, 0
        for g in sim.gpus:
            gsum = sum(r.rec.input_tokens + r.tokens_out
                       + (g.tok_epoch - r.tok_mark) for r in g.active)
            assert g.ctx_sum == gsum, (fid, g.gid)
            total += gsum
            count += len(g.active)
        assert sim._g_ctx_sum == total
        assert sim._g_ctx_n == count


def test_queued_prefill_tokens_incremental():
    sim = NodeSimulator(CFG, policy_4p4d(600), seed=0)
    from repro.core.goodput import RequestRecord
    from repro.core.simulator import SimRequest
    for i in range(12):
        sim.submit(SimRequest(RequestRecord(i, 0.0, 1000 + i, 16)))
    assert sim.queued_prefill_tokens() == \
        sum(r.rec.input_tokens for r in sim.q_prefill)
