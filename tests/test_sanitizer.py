"""Runtime invariant sanitizer (RAPID_SANITIZE): mutation tests that seed
each violation class and prove the sanitizer catches it at the next
dispatch, switch-resolution semantics, zero-residue-when-off, and
bit-identity of results with the sanitizer enabled."""
import dataclasses

import pytest

from repro.analysis.check.sanitize import (InvariantSanitizer,
                                           InvariantViolation,
                                           sanitize_enabled)
from repro.configs import get_config
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import ControllerConfig, policy_4p4d
from repro.core.events import EventLoop
from repro.core.fleet import FleetConfig, FleetManager
from repro.core.goodput import RequestRecord
from repro.core.simulator import SimRequest, Workload

CFG = get_config("llama31_8b")


def make_cluster(n_nodes=2, **kw):
    ctrl = dataclasses.replace(ControllerConfig(), allow_power=True,
                               allow_gpu=False, ttft_slo=2.0)
    return ClusterSimulator(CFG, policy_4p4d(500), n_nodes,
                            node_budget_w=4000.0, ctrl_cfg=ctrl,
                            cluster_cfg=ClusterConfig(allow_shift=True),
                            **kw)


def noop(kind, payload):
    pass


def dispatch_once(cs):
    """Force one dispatch so the sanitizer validates the mutated state."""
    cs.loop.push(cs.loop.now, noop, "sanity-probe")
    cs.loop.step()


# ---------------------------------------------------------------------------
# switch resolution + zero residue when off
# ---------------------------------------------------------------------------

def test_switch_resolution(monkeypatch):
    monkeypatch.delenv("RAPID_SANITIZE", raising=False)
    assert not sanitize_enabled()
    assert sanitize_enabled(True)
    for v in ("1", "true", "YES", "on"):
        monkeypatch.setenv("RAPID_SANITIZE", v)
        assert sanitize_enabled()
    monkeypatch.setenv("RAPID_SANITIZE", "0")
    assert not sanitize_enabled()
    monkeypatch.setenv("RAPID_SANITIZE", "1")
    assert not sanitize_enabled(False)      # explicit argument beats env


def test_off_by_default_leaves_no_hook(monkeypatch):
    monkeypatch.delenv("RAPID_SANITIZE", raising=False)
    assert EventLoop().sanitizer is None
    assert make_cluster().loop.sanitizer is None


def test_env_var_threads_through_cluster(monkeypatch):
    monkeypatch.setenv("RAPID_SANITIZE", "1")
    cs = make_cluster()
    assert isinstance(cs.loop.sanitizer, InvariantSanitizer)
    assert cs.loop.sanitizer.cluster is cs


# ---------------------------------------------------------------------------
# mutation tests: each seeded violation is caught
# ---------------------------------------------------------------------------

def test_budget_written_around_api_is_caught():
    cs = make_cluster(sanitize=True)
    assert cs.loop.sanitizer is not None
    # bypass shrink_budget/commit_budget (exactly what RC001 forbids in
    # source): caps still command 8 x 500 W against a 1000 W budget
    cs.nodes[0].pm.budget = 1000.0
    with pytest.raises(InvariantViolation, match="worst-case draw"):
        dispatch_once(cs)


def test_budget_inflation_breaks_facility_sum():
    cs = make_cluster(sanitize=True)
    # fits under the node's own GPU-cap ceiling, but the per-node budgets
    # now sum past the facility budget
    cs.nodes[0].pm.budget = 4500.0
    with pytest.raises(InvariantViolation, match="facility"):
        dispatch_once(cs)


def test_cap_written_around_api_is_caught():
    cs = make_cluster(sanitize=True)
    cs.nodes[0].pm.commanded[0] = 100.0     # below the 400 W spec floor
    with pytest.raises(InvariantViolation, match="spec floor"):
        dispatch_once(cs)


def test_event_posted_in_past_is_caught():
    cs = make_cluster(sanitize=True)
    cs.run(Workload.uniform(5, qps=4.0, in_tokens=512, out_tokens=8, seed=3))
    assert cs.loop.now > 1.0
    with pytest.raises(InvariantViolation, match="causality"):
        cs.loop.push(cs.loop.now - 1.0, noop, "stale")


def test_double_resident_request_is_caught():
    cs = make_cluster(sanitize=True)
    req = SimRequest(RequestRecord(1, 0.0, 2048, 16))
    cs.nodes[0].submit(req)
    cs.nodes[1].q_prefill.append(req)       # same object on two nodes
    with pytest.raises(InvariantViolation, match="residency"):
        dispatch_once(cs)


def test_energy_overcharge_is_caught():
    cs = make_cluster(sanitize=True)
    s = cs.run(Workload.uniform(5, qps=4.0, in_tokens=512, out_tokens=8,
                                seed=3))
    assert s.n_finished > 0 and cs.loop.sanitizer.checks > 0
    cs.records[0].energy_j += 1e9           # joules nobody drew
    with pytest.raises(InvariantViolation, match="energy"):
        dispatch_once(cs)


# ---------------------------------------------------------------------------
# read-only guarantee + fleet churn under the sanitizer
# ---------------------------------------------------------------------------

def test_sanitizer_is_read_only_bit_identical():
    def wl():
        return Workload.longbench_like(40, qps=8.0, seed=11)

    s_off = make_cluster().run(wl())
    cs_on = make_cluster(sanitize=True)
    s_on = cs_on.run(wl())
    assert cs_on.loop.sanitizer.checks > 0
    assert dataclasses.asdict(s_on) == dataclasses.asdict(s_off)


def test_fleet_churn_runs_clean_under_sanitizer():
    cs = make_cluster(n_nodes=3)
    fm = FleetManager(cs, FleetConfig(elastic=True), sanitize=True)
    assert cs.loop.sanitizer is not None
    fm.schedule_fail(5.0, 1)
    fm.schedule_join(12.0, 1)
    s = cs.run(Workload.uniform(40, qps=6.0, in_tokens=2048, out_tokens=64,
                                seed=5))
    assert cs.loop.sanitizer.checks > 0
    assert s.n_finished > 0
    cs.assert_facility_invariant()
