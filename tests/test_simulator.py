"""End-to-end simulator behaviour: reproduces the paper's qualitative claims
at reduced scale (fast versions of the Figure 5/8 experiments)."""
import dataclasses

import pytest

from repro.configs import get_config
from repro.core.controller import (ControllerConfig, StaticPolicy,
                                   policy_4p4d, policy_nonuniform)
from repro.core.simulator import NodeSimulator, Workload

CFG = get_config("llama31_8b")


def run(pol, wl, *, budget=4800.0, ctrl=None, coalesced=False):
    sim = NodeSimulator(CFG, pol, node_budget_w=budget, ctrl_cfg=ctrl,
                        coalesced=coalesced)
    return sim, sim.run(wl)


def test_all_requests_finish():
    wl = Workload.longbench_like(100, qps=4.0, seed=0)
    sim, s = run(policy_4p4d(600), wl)
    assert s.n_finished == s.n_total == 100
    assert s.p90_ttft > 0 and s.p90_tpot > 0


def test_low_load_meets_slo():
    wl = Workload.longbench_like(150, qps=3.0, seed=1)
    _, s = run(policy_4p4d(600), wl)
    assert s.slo_attainment > 0.95


def test_attainment_monotone_decreasing_in_load():
    att = []
    for qps in (4.0, 10.0, 16.0):
        wl = Workload.longbench_like(250, qps=qps, seed=2)
        _, s = run(policy_4p4d(600), wl)
        att.append(s.slo_attainment)
    assert att[0] >= att[1] >= att[2]
    assert att[0] - att[2] > 0.1


def test_nonuniform_beats_uniform_at_load():
    """Paper Fig 5a: 4P-750/4D-450 > 4P4D-600 under prefill pressure."""
    wl = Workload.longbench_like(600, qps=11.0, seed=3)
    _, s_uni = run(policy_4p4d(600), wl)
    wl = Workload.longbench_like(600, qps=11.0, seed=3)
    _, s_non = run(policy_nonuniform(750, 450), wl)
    assert s_non.slo_attainment >= s_uni.slo_attainment


def test_disagg_beats_coalesced_at_budget():
    wl = Workload.longbench_like(400, qps=10.0, seed=4)
    _, s_dis = run(policy_4p4d(600), wl)
    wl = Workload.longbench_like(400, qps=10.0, seed=4)
    _, s_coal = run(StaticPolicy(4, 4, 600, 600, "coal"), wl, coalesced=True)
    assert s_dis.slo_attainment > s_coal.slo_attainment


def test_dynamic_rapid_beats_static_on_phase_shift():
    """Paper Fig 8: DynGPU+DynPower is best on the two-phase workload."""
    ctrl = dataclasses.replace(ControllerConfig(), allow_power=True,
                               allow_gpu=True)
    wl = Workload.sonnet_phases(6.5, seed=5, n1=250, n2=250)
    _, s_static = run(policy_4p4d(600), wl)
    wl = Workload.sonnet_phases(6.5, seed=5, n1=250, n2=250)
    sim_dyn, s_dyn = run(policy_4p4d(600), wl, ctrl=ctrl)
    assert s_dyn.slo_attainment > s_static.slo_attainment
    assert len(sim_dyn.ctrl.trace) > 0
    # node budget invariant held throughout
    for _, caps, _ in sim_dyn.trace_caps:
        assert sum(caps) <= 4800.0 + 1e-6


def test_controller_moves_power_before_gpus():
    ctrl = dataclasses.replace(ControllerConfig(), allow_power=True,
                               allow_gpu=True)
    wl = Workload.sonnet_phases(6.5, seed=7, n1=200, n2=50)
    sim, _ = run(policy_4p4d(600), wl, ctrl=ctrl)
    kinds = [k for _, k, _ in sim.ctrl.trace]
    if "gpu" in kinds:
        assert kinds.index("power") < kinds.index("gpu")


def test_kv_transfer_counted_in_tpot_not_ttft():
    """Paper Section 4: transfer latency lands on TPOT."""
    from repro.core.costmodel import MI300X, CostModel
    from repro.core.power_model import mi300x
    cm = CostModel(CFG, MI300X, mi300x())
    assert cm.kv_transfer_time(8192) > 0
    wl = Workload.uniform(30, qps=2.0, in_tokens=4096, out_tokens=32, seed=8)
    sim, s = run(policy_4p4d(600), wl)
    # TTFT == prefill path only: compare to pure queue+exec estimate
    ex = cm.prefill_time(4096, 600)
    fast = [r for r in sim.records if r.ttft is not None]
    assert min(r.ttft for r in fast) == pytest.approx(ex, rel=0.05)
