"""Chaos harness + graceful degradation: power emergencies (force-throttle
and restore), correlated rack failures (one facility re-level), lossy/stalled
KV migrations (retry -> backoff -> KV-loss fallback), SLO-aware admission
shedding, and the determinism contract (bit-identical replay per seed)."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.chaos import ChaosConfig, ChaosEngine
from repro.core.cluster import (AdmissionConfig, ClusterConfig,
                                ClusterSimulator, PowerAwareRouter)
from repro.core.controller import ControllerConfig, policy_4p4d
from repro.core.fleet import FleetConfig, FleetManager, _Migration
from repro.core.goodput import RequestRecord
from repro.core.power_manager import PowerManager
from repro.core.simulator import SimRequest, Workload

CFG = get_config("llama31_8b")


def dyn(**kw):
    return dataclasses.replace(ControllerConfig(), allow_power=True,
                               allow_gpu=False, **kw)


def make_fleet(n_nodes=3, budget=4000.0, fcfg=None, **kw):
    cs = ClusterSimulator(CFG, policy_4p4d(500), n_nodes,
                          node_budget_w=budget,
                          ctrl_cfg=dyn(ttft_slo=2.0),
                          cluster_cfg=ClusterConfig(allow_shift=True),
                          **kw)
    fm = FleetManager(cs, fcfg or FleetConfig())
    return cs, fm


def wl(n=80, qps=6.0, seed=0, ttft=2.0, tpot=0.040):
    return Workload.uniform(n, qps=qps, in_tokens=4096, out_tokens=256,
                            seed=seed, ttft_slo=ttft, tpot_slo=tpot)


# ---------------------------------------------------------------------------
# PowerManager.emergency_shrink: tighten-only, floor-clamped, preemptive
# ---------------------------------------------------------------------------

def test_emergency_shrink_tightens_and_restores():
    pm = PowerManager(8, 4800.0, initial_caps=[600.0] * 8)
    t_ready, freed = pm.emergency_shrink(0.0, 3600.0)
    assert freed == pytest.approx(1200.0)
    assert pm._budget_target == pytest.approx(3600.0)
    pm.tick(t_ready)
    pm.commit_budget(t_ready)
    assert pm.budget == pytest.approx(3600.0)
    assert sum(pm.effective) <= 3600.0 + 1e-6
    # restore is the ordinary sink-side grow
    absorbed = pm.grow_budget(t_ready + 1.0, 1200.0)
    assert absorbed == pytest.approx(1200.0)
    assert pm.budget == pytest.approx(4800.0)


def test_emergency_shrink_never_loosens():
    pm = PowerManager(8, 4800.0, initial_caps=[600.0] * 8)
    pm.shrink_budget(0.0, 1500.0)              # in-flight: target 3300
    # an "emergency" above the current promise must be a no-op, not a grow
    t_ready, freed = pm.emergency_shrink(0.1, 4000.0)
    assert freed == 0.0 and pm._budget_target == pytest.approx(3300.0)
    # a tighter emergency preempts the in-flight shrink
    t_ready, freed = pm.emergency_shrink(0.2, 3250.0)
    assert freed == pytest.approx(50.0)
    assert pm._budget_target == pytest.approx(3250.0)


def test_emergency_shrink_clamps_at_cap_floor():
    pm = PowerManager(8, 4800.0, initial_caps=[600.0] * 8)
    t_ready, freed = pm.emergency_shrink(0.0, 100.0)
    assert pm._budget_target == pytest.approx(pm.budget_floor_w)
    assert freed == pytest.approx(4800.0 - pm.budget_floor_w)


# ---------------------------------------------------------------------------
# Facility power emergency: begin -> enforced -> end, caps restored
# ---------------------------------------------------------------------------

def test_emergency_force_throttles_and_restores():
    cs, fm = make_fleet(sanitize=True)
    fm.schedule_emergency(3.0, 0.5, duration_s=5.0)
    s = cs.run(wl())
    kinds = [k for _, k, _ in fm.emergency_trace]
    assert kinds == ["begin", "enforced", "end"]
    (t_b, _, lim_b), (t_e, _, lim_e), (t_r, _, lim_r) = fm.emergency_trace
    assert t_b == pytest.approx(3.0) and t_r == pytest.approx(8.0)
    assert lim_b == lim_e == pytest.approx(0.5 * cs.facility_budget_w)
    assert lim_r == pytest.approx(cs.facility_budget_w)
    # committed budgets obeyed the slashed limit throughout enforcement
    # (up to the per-node cap floors, which a powered node cannot go below)
    for t, budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6
        if t_e <= t < t_r:
            floors = sum(nd.pm.budget_floor_w
                         for nd, b in zip(cs.nodes, budgets) if b > 0)
            assert total <= max(lim_e, floors) + 1e-6, (t, budgets)
    # watts re-leveled back to nameplate after the window
    assert sum(nd.pm.budget for nd in cs.nodes) == \
        pytest.approx(cs.facility_budget_w)
    assert cs.facility_limit_w == pytest.approx(cs.facility_budget_w)
    assert not fm.emergency_active and not fm._emergency_enforced
    assert s.n_finished > 0


def test_join_during_emergency_grant_is_clamped():
    """Regression for the pending-join hazard: a node whose join commits
    inside the emergency window must receive a grant clamped against the
    slashed limit, not against nameplate headroom."""
    cs, fm = make_fleet(sanitize=True)
    fm.schedule_leave(1.0, 2)
    fm.schedule_emergency(4.0, 0.9, duration_s=6.0)
    fm.schedule_join(6.0, 2)                 # commits mid-emergency
    cs.run(wl())
    limit = 0.9 * cs.facility_budget_w
    t_e = next(t for t, k, _ in fm.emergency_trace if k == "enforced")
    t_r = next(t for t, k, _ in fm.emergency_trace if k == "end")
    joined = [t for t, k, n in fm.churn_trace if k == "join" and n == 2]
    assert any(t_e <= t < t_r for t in joined), \
        "join must land inside the emergency window"
    for t, budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6
        if t_e <= t < t_r:
            assert total <= limit + 1e-6, (t, budgets)
    # all three nodes end powered at nameplate after restore
    assert all(nd.pm.powered for nd in cs.nodes)
    assert sum(nd.pm.budget for nd in cs.nodes) == \
        pytest.approx(cs.facility_budget_w)


def test_join_during_deep_emergency_is_deferred():
    """When the slashed limit leaves less headroom than the joiner's cap
    floor, the join must defer and retry — never power on over the limit."""
    cs, fm = make_fleet(sanitize=True)
    fm.schedule_leave(1.0, 2)
    fm.schedule_emergency(4.0, 0.5, duration_s=6.0)
    fm.schedule_join(6.0, 2)
    cs.run(wl())
    t_r = next(t for t, k, _ in fm.emergency_trace if k == "end")
    deferred = [t for t, k, n in fm.churn_trace
                if k == "join_deferred" and n == 2]
    assert deferred, "a too-tight emergency must defer the join"
    # the node eventually joined — after the window lifted the limit
    assert cs.nodes[2].pm.powered
    assert cs.active[2]
    for t, budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6
    assert sum(nd.pm.budget for nd in cs.nodes) == \
        pytest.approx(cs.facility_budget_w)
    assert min(deferred) < t_r


def test_overlapping_emergencies_tightest_wins():
    cs, fm = make_fleet(sanitize=True)
    fm.schedule_emergency(2.0, 0.7, duration_s=8.0)
    fm.schedule_emergency(4.0, 0.5, duration_s=2.0)   # tighter, nested
    cs.run(wl())
    limits = [w for _, k, w in fm.emergency_trace if k == "begin"]
    assert limits == [pytest.approx(0.7 * cs.facility_budget_w),
                      pytest.approx(0.5 * cs.facility_budget_w)]
    # inner end relaxes back to the outer limit; outer end restores
    relaxes = [w for _, k, w in fm.emergency_trace if k == "relax"]
    assert relaxes == [pytest.approx(0.7 * cs.facility_budget_w)]
    ends = [w for _, k, w in fm.emergency_trace if k == "end"]
    assert ends == [pytest.approx(cs.facility_budget_w)]
    assert sum(nd.pm.budget for nd in cs.nodes) == \
        pytest.approx(cs.facility_budget_w)


def test_autoscaler_holds_during_emergency():
    from repro.core.autoscale import AutoscaleConfig, PredictiveAutoscaler
    cs, fm = make_fleet(sanitize=True)
    asc = PredictiveAutoscaler(fm, AutoscaleConfig(period_s=1.0))
    asc.start()
    fm.schedule_emergency(3.0, 0.5, duration_s=5.0)
    cs.run(wl())
    held = [d for d in asc.decision_trace if d[1] == "emergency_hold"]
    assert held, "autoscaler must hold (not scale) inside the window"
    assert all(3.0 <= d[0] <= 8.0 + 1e-6 for d in held)


# ---------------------------------------------------------------------------
# Correlated rack failure: k nodes die, ONE facility re-level
# ---------------------------------------------------------------------------

def test_fail_group_single_relevel():
    cs, fm = make_fleet(n_nodes=4, sanitize=True)
    fm.schedule_fail_group(5.0, [2, 3])
    s = cs.run(wl(n=90, qps=7.0))
    fails = [(t, k, n) for t, k, n in fm.churn_trace if k == "fail"]
    assert [(k, n) for _, k, n in fails] == [("fail", 2), ("fail", 3)]
    assert all(t == pytest.approx(5.0) for t, _, _ in fails)
    # survivors absorb the pooled watts in ONE grow each, not one per victim
    for nid in (0, 1):
        grows = [(t, w) for t, w in cs.nodes[nid].pm.budget_history
                 if t >= 5.0 and w > 4000.0]
        assert len(grows) == 1, grows
        assert grows[0][1] == pytest.approx(6000.0)   # clamped by GPU ceiling
    for t, budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6
    # victims' in-flight work re-entered and the run drained fully
    assert cs.n_unfinished() == 0
    assert s.n_finished == len(cs.records)


# ---------------------------------------------------------------------------
# Migration engine: pipelined bursts, stalls, retry -> KV-loss fallback
# ---------------------------------------------------------------------------

def _mig(fm, rid, src=0, dt=0.2, deadline=100.0):
    rec = RequestRecord(rid, 0.0, 512, 64)
    return _Migration(SimRequest(rec), src, "drain", 512, dt, deadline)


def test_drain_burst_pays_one_rpc_setup():
    cs, fm = make_fleet()
    lat = fm.cfg.migrate_latency_s
    fm._start_transfer(_mig(fm, 0, dt=0.2))
    assert fm._link_free[0] == pytest.approx(lat + 0.2)
    fm._start_transfer(_mig(fm, 1, dt=0.3))         # queued behind, no setup
    assert fm._link_free[0] == pytest.approx(lat + 0.5)
    # an idle link pays the setup again at the next burst head
    t2 = lat + 0.5 + 1.0
    fm.loop.now = t2
    fm._start_transfer(_mig(fm, 2, dt=0.1))
    assert fm._link_free[0] == pytest.approx(t2 + lat + 0.1)


def test_link_stall_delays_the_burst():
    cs, fm = make_fleet(sanitize=True)
    ch = ChaosEngine(fm, ChaosConfig(seed=0))
    ch.schedule_link_fault(3.0, 2, 2.0, mode="stall")
    fm.schedule_leave(3.0, 2)
    fm.schedule_join(9.0, 2)
    cs.run(wl())
    assert fm.stall_trace, "stalled transfers must be recorded"
    assert not fm.kv_loss_trace, "a stall is ridden out, never lost"
    # every stalled transfer resumed at/after the window end
    assert all(resume >= 5.0 - 1e-9 for _, _, _, resume in fm.stall_trace)
    assert cs.n_unfinished() == 0


def test_link_fault_retries_then_falls_back_to_kv_loss():
    cs, fm = make_fleet(
        sanitize=True,
        fcfg=FleetConfig(migrate_max_retries=2, migrate_deadline_s=0.5))
    ch = ChaosEngine(fm, ChaosConfig(seed=0))
    ch.schedule_link_fault(3.0, 2, 50.0, mode="fail")   # outlasts deadline
    fm.schedule_leave(3.0, 2)
    cs.run(wl())
    assert fm.retry_trace, "failed transfers must retry first"
    assert fm.kv_loss_trace, "deadline exhaustion must degrade to KV loss"
    assert all(why in ("retries", "deadline")
               for _, _, _, why in fm.kv_loss_trace)
    # fallen-back requests re-entered from scratch and the run drained
    assert cs.n_unfinished() == 0


def test_naive_arm_loses_kv_immediately():
    cs, fm = make_fleet(sanitize=True,
                        fcfg=FleetConfig(migrate_max_retries=0))
    ch = ChaosEngine(fm, ChaosConfig(seed=0))
    ch.schedule_link_fault(3.0, 2, 1.0, mode="fail")
    fm.schedule_leave(3.0, 2)
    cs.run(wl())
    assert not fm.retry_trace, "retries disabled on the naive arm"
    assert fm.kv_loss_trace
    assert cs.n_unfinished() == 0


def test_retries_beat_the_fault_window():
    """A short fault window: backoff carries the transfer past the window
    and it lands with KV intact — no losses at all."""
    cs, fm = make_fleet(sanitize=True, fcfg=FleetConfig(
        migrate_max_retries=6, migrate_backoff_s=0.1,
        migrate_deadline_s=10.0))
    ch = ChaosEngine(fm, ChaosConfig(seed=0))
    ch.schedule_link_fault(3.0, 2, 0.3, mode="fail")
    fm.schedule_leave(3.0, 2)
    fm.schedule_join(9.0, 2)
    cs.run(wl())
    assert fm.retry_trace
    assert not fm.kv_loss_trace
    assert cs.n_unfinished() == 0


# ---------------------------------------------------------------------------
# SLO-aware admission control + shed accounting
# ---------------------------------------------------------------------------

def test_admission_off_is_bitidentical_to_no_admission():
    def fp(adm):
        cs = ClusterSimulator(CFG, policy_4p4d(500), 2,
                              node_budget_w=4000.0,
                              ctrl_cfg=dyn(ttft_slo=2.0), seed=7,
                              admission=adm)
        cs.run(wl(n=50, qps=5.0))
        return [(r.rid, r.prefill_done, r.finish, r.energy_j, r.shed_t)
                for r in cs.records]
    assert fp(None) == fp(AdmissionConfig(slo_aware=False))


def test_overload_sheds_and_accounts():
    cs = ClusterSimulator(CFG, policy_4p4d(500), 1, node_budget_w=4000.0,
                          ctrl_cfg=dyn(ttft_slo=0.5), seed=7,
                          admission=AdmissionConfig(slo_aware=True))
    # a hard overload against a tight SLO: shedding must kick in
    s = cs.run(wl(n=120, qps=40.0, ttft=0.5))
    assert s.n_shed > 0
    shed = [r for r in cs.records if r.shed_t is not None]
    assert len(shed) == s.n_shed == cs.n_shed
    assert all(r.finish is None for r in shed)
    assert s.shed_energy_j == pytest.approx(
        sum(r.energy_j for r in shed))
    assert "shed" in s.row()
    assert cs.n_unfinished() == 0            # sheds terminate the ledger
    assert s.n_good + s.n_shed <= len(cs.records)


def test_deferred_requests_terminally_resolve():
    cs = ClusterSimulator(CFG, policy_4p4d(500), 1, node_budget_w=4000.0,
                          ctrl_cfg=dyn(ttft_slo=1.0), seed=7,
                          admission=AdmissionConfig(slo_aware=True,
                                                    defer_frac=0.5,
                                                    shed_frac=4.0))
    cs.run(wl(n=80, qps=25.0, ttft=1.0))
    assert cs.router.defer_trace, "overload this deep must defer"
    assert cs.n_unfinished() == 0
    for r in cs.records:
        assert (r.finish is not None) or (r.shed_t is not None)


def test_value_density_orders_shedding():
    r = PowerAwareRouter()
    hi = SimRequest(RequestRecord(0, 0.0, 100, 900))     # decode-heavy
    lo = SimRequest(RequestRecord(1, 0.0, 8000, 16))     # prefill-heavy
    assert r._density(hi) > r._density(lo)


def test_shed_on_empty_queue_is_age_driven():
    """Shedding needs no queue: a request that aged past its shed
    threshold before reaching the router (defer storm, requeue latency)
    is shed even against a completely idle cluster — the projection is
    time-already-lost plus load, and the load term can be zero."""
    cs = ClusterSimulator(CFG, policy_4p4d(500), 1, node_budget_w=4000.0,
                          ctrl_cfg=dyn(ttft_slo=0.5), seed=7,
                          admission=AdmissionConfig(slo_aware=True))
    fresh = SimRequest(RequestRecord(0, 5.0, 4096, 256, ttft_slo=0.5))
    aged = SimRequest(RequestRecord(1, 0.0, 4096, 256, ttft_slo=0.5))
    verdict, node = cs.router.decide(5.0, cs.nodes, fresh)
    assert verdict == "admit" and node is not None
    verdict, node = cs.router.decide(5.0, cs.nodes, aged)
    assert verdict == "shed" and node is None
    assert cs.router.shed_trace[-1][1] == 1


def test_all_requests_shed_terminates_with_zero_goodput():
    """Total shed is a terminal state, not a hang: when every request is
    hopeless on arrival the run ends with n_shed == n, zero goodput, and
    zero shed energy (nothing was ever admitted)."""
    cs = ClusterSimulator(CFG, policy_4p4d(500), 1, node_budget_w=4000.0,
                          ctrl_cfg=dyn(ttft_slo=0.5), seed=7,
                          admission=AdmissionConfig(slo_aware=True,
                                                    shed_frac=1.0))
    # pre-seed aged arrivals (chaos-surge style: arrival stamp t=0,
    # delivered at t=1): every projection opens at 2x the SLO
    for i in range(12):
        rec = RequestRecord(i, 0.0, 4096, 256, ttft_slo=0.5,
                            tpot_slo=0.040)
        cs.records.append(rec)
        cs.loop.push(1.0, cs._handle, "arrival", (SimRequest(rec), None))
    s = cs.run(Workload([]))
    assert s.n_shed == 12 == cs.n_shed
    assert all(r.shed_t is not None and r.finish is None
               for r in cs.records)
    assert s.shed_energy_j == 0.0
    assert s.n_good == 0
    assert cs.n_unfinished() == 0


def test_value_density_ties_shed_deterministically():
    """An all-identical workload makes every value-density comparison a
    tie; the tie-break (arrival order through the rotating router) must
    be deterministic — same seed, same shed set, bit-identical records."""
    hi = SimRequest(RequestRecord(0, 0.0, 512, 512))
    lo = SimRequest(RequestRecord(1, 0.0, 1024, 1024))
    assert PowerAwareRouter()._density(hi) == PowerAwareRouter()._density(lo)

    def fp():
        cs = ClusterSimulator(CFG, policy_4p4d(500), 1,
                              node_budget_w=4000.0,
                              ctrl_cfg=dyn(ttft_slo=0.5), seed=7,
                              admission=AdmissionConfig(slo_aware=True))
        s = cs.run(wl(n=60, qps=40.0, ttft=0.5))
        return s.n_shed, [(r.rid, r.finish, r.shed_t, r.energy_j)
                          for r in cs.records]
    n_shed_a, fp_a = fp()
    n_shed_b, fp_b = fp()
    assert n_shed_a > 0
    assert fp_a == fp_b


def test_shed_after_partial_prefill_keeps_spent_joules():
    """A request that burned prefill joules, lost its node, and was then
    shed at re-admission must carry those joules into shed_energy_j —
    wasted work stays on the bill (reset_for_requeue keeps energy)."""
    cs, fm = make_fleet(n_nodes=2, fcfg=FleetConfig(
        requeue_latency_s=0.6), admission=AdmissionConfig(slo_aware=True))
    fm.schedule_fail(0.05, 0)       # mid-prefill, the serving node dies
    # admitted while idle; the batch energy is charged when prefill
    # starts, then the failure requeues it and the defer loop ages it
    # past the shed threshold
    s = cs.run(Workload([(0.0, 4096, 256, 0.5, 0.040)]))
    rec = cs.records[0]
    assert rec.shed_t is not None and rec.finish is None
    assert rec.energy_j > 0.0, "partial prefill joules were spent"
    assert s.shed_energy_j == pytest.approx(rec.energy_j)
    assert cs.n_unfinished() == 0


# ---------------------------------------------------------------------------
# ChaosEngine: surge pre-seeding + seeded determinism contract
# ---------------------------------------------------------------------------

def test_surge_preseeds_ledger_and_terminates():
    cs, fm = make_fleet(n_nodes=2, sanitize=True)
    ch = ChaosEngine(fm, ChaosConfig(seed=11))
    ch.schedule_surge(2.0, 15, qps=30.0)
    s = cs.run(wl(n=30, qps=4.0))
    assert len(cs.records) == 45
    assert [r.rid for r in cs.records] == list(range(45))
    assert all(r.arrival >= 2.0 for r in cs.records[30:])
    assert cs.n_unfinished() == 0
    assert s.n_finished == 45


def test_chaos_replay_is_bitidentical_per_seed():
    def run(seed):
        cs, fm = make_fleet(n_nodes=2, seed=7)
        ch = ChaosEngine(fm, ChaosConfig(seed=seed))
        ch.schedule_surge(1.0, 10, qps=20.0)
        ch.schedule_link_fault(2.0, 1, 0.5, mode="fail")
        fm.schedule_leave(2.0, 1)
        fm.schedule_join(6.0, 1)
        fm.schedule_emergency(3.0, 0.6, duration_s=2.0)
        cs.run(wl(n=30, qps=5.0))
        return [(r.rid, r.arrival, r.prefill_done, r.finish, r.energy_j,
                 r.shed_t) for r in cs.records]
    a, b, c = run(5), run(5), run(6)
    assert a == b, "same seed must replay bit-identically"
    assert a != c, "a different seed must actually perturb the run"


def test_inject_is_deterministic_and_runs_sanitized():
    def run():
        cs, fm = make_fleet(n_nodes=3, sanitize=True)
        ch = ChaosEngine(fm, ChaosConfig(seed=3))
        ch.inject(horizon_s=10.0, rejoin_after_s=3.0)
        cs.run(wl(n=40, qps=5.0))
        return (cs.loop.sanitizer.checks,
                [(r.rid, r.finish, r.energy_j, r.shed_t)
                 for r in cs.records])
    (checks_a, fp_a), (checks_b, fp_b) = run(), run()
    assert checks_a == checks_b and checks_a > 0
    assert fp_a == fp_b


def test_rc006_chaos_engine_owns_the_fault_hook():
    cs, fm = make_fleet(n_nodes=2)
    assert fm.link_fault_fn is None
    ch = ChaosEngine(fm)
    assert fm.link_fault_fn == ch._link_fault
    # clean windows -> clean verdicts; overlap -> deterministic verdict
    assert ch._link_fault(0, 0.0, 1.0) is None
    ch.schedule_link_fault(5.0, 0, 1.0, mode="fail")
    assert ch._link_fault(0, 0.0, 1.0) is None          # before the window
    kind, t = ch._link_fault(0, 5.2, 1.0)
    assert kind == "fail" and t == pytest.approx(5.2 + 0.5 * 1.0)
    ch.schedule_link_fault(8.0, 0, 1.0, mode="stall")
    kind, t = ch._link_fault(0, 8.5, 1.0)
    assert kind == "stall" and t == pytest.approx(9.0)
