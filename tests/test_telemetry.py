"""Control-plane fault tolerance (core/telemetry.py + cluster/fleet/
autoscale wiring): telemetry-bus bit-identity and degraded windows
(freeze / dropout / sample-and-hold), coordinator staleness holds,
heartbeat failure detection (false suspicion, physical death, split-brain
fencing), controller crash windows (headless admission, epoch-fenced
budget grants, restart re-level), the snapshot+replay recovery golden
test, and the sanitizer's epoch-fence check."""
import dataclasses

import pytest

from repro.analysis.check.sanitize import InvariantViolation
from repro.configs import get_config
from repro.core.autoscale import AutoscaleConfig, PredictiveAutoscaler
from repro.core.chaos import ChaosConfig, ChaosEngine
from repro.core.cluster import (AdmissionConfig, ClusterConfig,
                                ClusterSimulator)
from repro.core.controller import ControllerConfig, policy_4p4d
from repro.core.events import EventLoop
from repro.core.fleet import FleetConfig, FleetManager
from repro.core.goodput import RequestRecord
from repro.core.simulator import Workload
from repro.core.telemetry import (ControlJournal, HeartbeatConfig,
                                  HeartbeatDetector, TelemetryConfig)

CFG = get_config("llama31_8b")


def dyn(**kw):
    return dataclasses.replace(ControllerConfig(), allow_power=True,
                               allow_gpu=False, **kw)


def make_fleet(n_nodes=3, budget=4000.0, fcfg=None, **kw):
    cs = ClusterSimulator(CFG, policy_4p4d(500), n_nodes,
                          node_budget_w=budget,
                          ctrl_cfg=dyn(ttft_slo=2.0),
                          cluster_cfg=ClusterConfig(allow_shift=True),
                          seed=7, **kw)
    fm = FleetManager(cs, fcfg or FleetConfig())
    return cs, fm


def wl(n=60, qps=6.0, seed=0, ttft=2.0):
    return Workload.uniform(n, qps=qps, in_tokens=4096, out_tokens=256,
                            seed=seed, ttft_slo=ttft, tpot_slo=0.040)


# ---------------------------------------------------------------------------
# TelemetryBus: clean-path bit-identity and degraded windows
# ---------------------------------------------------------------------------

def test_fresh_bus_reads_are_bit_identical_to_direct_reads():
    cs, _fm = make_fleet()
    tb = cs.telemetry
    for nd in cs.nodes:
        assert tb.router_load(nd, 4096) == nd.router_load(4096)
        assert tb.prefill_capacity_tps(nd) == nd.prefill_capacity_tps()
        assert tb.marginal_jpt(nd, 4096, 256) == \
            nd.marginal_joules_per_token(4096, 256)
        assert tb.staleness(nd) == 0.0
    assert tb.max_staleness(cs.nodes) == 0.0


def test_freeze_serves_last_known_good_and_staleness_grows():
    cs, _fm = make_fleet(n_nodes=1)
    tb = cs.telemetry
    nd = cs.nodes[0]
    before = tb.router_load(nd, 0)
    tb.telemetry_fault_fn = lambda nid, now: "freeze"
    cs.loop.now = 5.0
    # live node state changes under the frozen pipeline...
    nd.queued_prefill_tokens = lambda: 10 ** 6
    assert nd.router_load(0) > before
    # ...but the bus keeps serving the last-known-good view, and the
    # freshness clock reports exactly how old that view is
    assert tb.router_load(nd, 0) == before
    assert tb.staleness(nd) == 5.0
    assert tb.max_staleness([nd]) == 5.0
    # the window lifting does not rewrite history: staleness stays until
    # the next read actually samples live
    tb.telemetry_fault_fn = None
    assert tb.staleness(nd) == 5.0
    tb.router_load(nd, 0)
    assert tb.staleness(nd) == 0.0


def test_sample_and_hold_bounds_staleness_by_the_period():
    cs, _fm = make_fleet(n_nodes=1)
    tb = cs.telemetry
    nd = cs.nodes[0]
    tb.telemetry_fault_fn = lambda nid, now: ("sample", 1.0)
    tb.router_load(nd, 0)               # first contact samples live
    assert tb.staleness(nd) == 0.0
    cs.loop.now = 0.5
    tb.router_load(nd, 0)               # inside the period: held
    assert tb.staleness(nd) == 0.5
    cs.loop.now = 1.5
    tb.router_load(nd, 0)               # period expired: resamples
    assert tb.staleness(nd) == 0.0
    # only a dropout window swallows heartbeats; sample/freeze do not
    assert not tb.heartbeat_blocked(0, 1.5)
    tb.telemetry_fault_fn = lambda nid, now: "drop"
    assert tb.heartbeat_blocked(0, 1.5)


def test_coordinator_holds_power_plan_on_stale_telemetry():
    def run(act_on_stale):
        cs, fm = make_fleet(sanitize=True, telemetry=TelemetryConfig(
            act_on_stale=act_on_stale))
        ch = ChaosEngine(fm, ChaosConfig(seed=0))
        ch.schedule_telemetry_freeze(2.0, 4.0)
        cs.run(wl())
        return cs
    cs = run(False)
    assert cs.hold_trace, "the freeze must trip the staleness bound"
    for t, reason, stale_s in cs.hold_trace:
        assert reason == "stale"
        assert stale_s > cs.telemetry.cfg.max_staleness_s
        assert 2.0 < t < 6.5          # holds only while the view is old
    # the naive config records the same violations but keeps acting
    assert run(True).hold_trace


# ---------------------------------------------------------------------------
# HeartbeatDetector: suspicion, death, split-brain fencing
# ---------------------------------------------------------------------------

def test_false_suspicion_reintegrates_without_kv_loss():
    cs, fm = make_fleet(sanitize=True)
    det = HeartbeatDetector(fm, HeartbeatConfig())
    det.start()
    ch = ChaosEngine(fm, ChaosConfig(seed=0))
    # node 1's heartbeats swallowed long enough to suspect, not to kill
    ch.schedule_telemetry_dropout(3.0, 1.2, node_ids=[1])
    cs.run(wl())
    assert det.drop_trace, "the dropout must have swallowed heartbeats"
    kinds = [(k, n) for _, k, n in fm.churn_trace]
    assert ("suspected", 1) in kinds and ("reintegrated", 1) in kinds
    assert not any(k in ("fail", "die", "fenced", "dead_detected")
                   for k, _ in kinds)
    assert not fm.kv_loss_trace and not fm.requeue_trace
    assert cs.active[1] and cs.nodes[1].pm.powered
    assert det.state[1] == "alive"
    assert cs.n_unfinished() == 0


def test_node_death_requeues_at_detection_not_at_death():
    cs, fm = make_fleet(sanitize=True)
    det = HeartbeatDetector(fm, HeartbeatConfig())
    det.start()
    ch = ChaosEngine(fm, ChaosConfig(seed=0))
    ch.schedule_node_death(3.0, 2)
    fm.schedule_join(9.0, 2)
    cs.run(wl())
    t_die = next(t for t, k, n in fm.churn_trace if k == "die" and n == 2)
    t_det = next(t for t, k, n in fm.churn_trace
                 if k == "dead_detected" and n == 2)
    assert t_die == pytest.approx(3.0)
    # detection is gated on the heartbeat timeout — the latency is real
    # (the age clock starts at the LAST heartbeat, up to one period
    # before the death itself)
    assert t_det >= 3.0 + det.cfg.dead_after_s - det.cfg.check_period_s
    assert [k for _, n, k in det.trace if n == 2][:2] == \
        ["suspected", "dead"]
    # stranded work and watts recover at DETECTION time, not death time
    assert all(t >= t_det for t, _rid, nid in fm.requeue_trace if nid == 2)
    assert 2 not in fm._limbo
    # the node rejoined and heartbeated back to monitored-alive
    assert cs.active[2] and det.state[2] == "alive"
    assert cs.n_unfinished() == 0


def test_dead_timeout_fences_a_live_but_unheard_node():
    cs, fm = make_fleet(sanitize=True)
    det = HeartbeatDetector(fm, HeartbeatConfig())
    det.start()
    ch = ChaosEngine(fm, ChaosConfig(seed=0))
    # heartbeats swallowed past dead_after_s: the detector must fence the
    # node even though it is physically fine (split-brain guard)
    ch.schedule_telemetry_dropout(3.0, 4.0, node_ids=[1])
    cs.run(wl())
    kinds = [(k, n) for _, k, n in fm.churn_trace]
    assert ("suspected", 1) in kinds and ("fenced", 1) in kinds
    assert det.state[1] == "dead"
    assert not cs.active[1] and not cs.nodes[1].pm.powered
    # fenced watts redistributed; conservation held throughout
    for _t, _budgets, total in cs.budget_trace:
        assert total <= cs.facility_budget_w + 1e-6
    assert cs.n_unfinished() == 0


# ---------------------------------------------------------------------------
# Controller crash: headless fail-safe mode, epoch fencing, restart
# ---------------------------------------------------------------------------

def test_controller_crash_headless_admission_and_restart():
    cs, fm = make_fleet(sanitize=True,
                        admission=AdmissionConfig(slo_aware=True))
    ch = ChaosEngine(fm, ChaosConfig(seed=0))
    ch.schedule_controller_crash(3.0, 4.0)
    cs.run(wl())
    assert [k for _, k, _ in cs.crash_trace] == ["crash", "restart"]
    (t_c, _, e0), (t_r, _, e1) = cs.crash_trace
    assert t_c == pytest.approx(3.0) and t_r == pytest.approx(7.0)
    assert e0 == 0 and e1 == 1 == cs.controller_epoch
    assert not cs.controller_down
    # the headless window still admits traffic (local round-robin +
    # node-local shedding) and still probes the facility invariant
    assert any(3.0 <= t < 7.0 for t, _nid in cs.router.trace)
    assert any(3.0 <= t < 7.0 for t, _b, _tot in cs.budget_trace)
    # watts fully re-leveled by the restart
    assert sum(nd.pm.budget for nd in cs.nodes) == \
        pytest.approx(cs.facility_budget_w)
    assert cs.n_unfinished() == 0


def _run_with_inflight_grant(crash_duration_fn):
    """A budget shift whose grant matures at ``t_ready``, with a
    controller crash window scheduled by ``crash_duration_fn(t_ready)``."""
    cs, fm = make_fleet(n_nodes=2, sanitize=True)
    t_ready, freed = cs.nodes[0].pm.shrink_budget(0.0, 200.0)
    assert freed > 0.0
    cs._inflight.update((0, 1))
    cs.loop.push(t_ready, cs._handle, "budget_ready", (0, 1, freed, 0))
    fm.schedule_controller_crash(0.0, crash_duration_fn(t_ready))
    cs.run(wl(n=30))
    return cs, freed


def test_grant_maturing_inside_crash_window_is_fenced():
    cs, freed = _run_with_inflight_grant(lambda t_ready: t_ready + 1.0)
    t_f, src, dst, w, epoch = cs.fence_trace[0]
    assert (src, dst, w, epoch) == (0, 1, freed, 0)
    # fail-safe guard band: the source's cap lowering still committed (no
    # grant ever exceeds the promise), the sink got nothing against the
    # dead epoch, and the restart re-level reclaimed the headroom
    assert all(e_issued == e_now and not down for
               _t, _s, _d, _w, e_issued, e_now, down in cs.grant_trace)
    assert sum(nd.pm.budget for nd in cs.nodes) == \
        pytest.approx(cs.facility_budget_w)
    assert cs.n_unfinished() == 0


def test_grant_from_previous_epoch_is_fenced_after_restart():
    # crash ends BEFORE the grant matures: at maturity the controller is
    # back up but the epoch has advanced — the stale grant must still void
    cs, freed = _run_with_inflight_grant(lambda t_ready: 0.5 * t_ready)
    assert cs.controller_epoch == 1
    t_f, src, dst, w, epoch = cs.fence_trace[0]
    assert (src, dst, w, epoch) == (0, 1, freed, 0)
    # here the restart re-level ran BEFORE the grant matured, so the
    # fenced watts stay stranded as guard band — the fail-safe errs
    # strictly UNDER the facility cap, never over it
    total = sum(nd.pm.budget for nd in cs.nodes)
    assert total == pytest.approx(cs.facility_budget_w - freed)
    assert total <= cs.facility_budget_w + 1e-6


def test_sanitizer_flags_epoch_violating_grant():
    cs, _fm = make_fleet(n_nodes=2, sanitize=True)
    san = cs.loop.sanitizer
    # a grant committed against a stale epoch must never appear
    cs.grant_trace.append((1.0, 0, 1, 200.0, 0, 1, False))
    with pytest.raises(InvariantViolation):
        san._check_epoch_fence()
    cs2, _fm2 = make_fleet(n_nodes=2, sanitize=True)
    # ...nor a grant committed while the controller is down
    cs2.grant_trace.append((1.0, 0, 1, 200.0, 1, 1, True))
    with pytest.raises(InvariantViolation):
        cs2.loop.sanitizer._check_epoch_fence()


# ---------------------------------------------------------------------------
# Crash-recoverable coordination: journal + snapshot/replay golden test
# ---------------------------------------------------------------------------

def test_control_journal_records_snapshots_and_replays():
    loop = EventLoop()
    j = ControlJournal(loop)
    loop.publish("arrival", RequestRecord(0, 0.0, 100, 10))
    loop.now = 1.0
    loop.publish("arrival", RequestRecord(1, 1.0, 200, 10))
    assert j.entries == [(0.0, 100), (1.0, 200)]
    j.snapshot(("state1",))
    loop.now = 2.0
    loop.publish("arrival", RequestRecord(2, 2.0, 300, 10))
    j.snapshot(("state2",))              # latest-snapshot-wins slot
    t, n, state = j.latest()
    assert (t, n, state) == (2.0, 3, ("state2",))
    assert j.n_snapshots == 2
    assert j.replay_from(n) == []
    assert j.replay_from(1) == [(1.0, 200), (2.0, 300)]


def test_golden_recovery_is_bitidentical_to_an_uncrashed_run():
    """The headline recovery guarantee: a controller that crashed, lost
    its in-memory state, and rebuilt from snapshot + journal replay ends
    the run with forecaster state bit-identical to a twin controller that
    never crashed — under identical telemetry (admission off and static
    membership keep the two data planes exactly equal)."""
    def run(crash):
        cs = ClusterSimulator(CFG, policy_4p4d(500), 2,
                              node_budget_w=4000.0,
                              ctrl_cfg=dyn(ttft_slo=2.0), seed=7,
                              cluster_cfg=ClusterConfig(allow_shift=False),
                              sanitize=True)
        fm = FleetManager(cs, FleetConfig(elastic=True))
        az = PredictiveAutoscaler(
            fm, AutoscaleConfig(mode="static", period_s=2.0))
        az.start()
        if crash:
            fm.schedule_controller_crash(4.0, 5.0)
        cs.run(wl())
        return cs, az
    cs_a, az_a = run(True)
    cs_b, az_b = run(False)
    # identical telemetry: the durable journal saw the same stream even
    # though the crashed controller's process missed five seconds of it
    assert az_a.journal.entries == az_b.journal.entries
    assert az_a.journal.n_snapshots > 0
    assert any(k == "recovered" for _t, k, *_rest in az_a.decision_trace)
    T = max(cs_a.loop.now, cs_b.loop.now)
    # bit-identity gate #1: the live post-recovery forecaster
    assert az_a.forecaster.state(T) == az_b.forecaster.state(T)
    # bit-identity gate #2: the recovery protocol itself, replayed cold
    f, _last_action = az_a._rebuild()
    assert f.state(T) == az_b.forecaster.state(T)
