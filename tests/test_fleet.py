"""Elastic fleet subsystem: membership churn (join/leave/fail) with
facility-level power redistribution, the KV-aware cross-node migration
engine, per-request energy accounting, TPU-v5e node wiring, and the
joules router policy."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.controller import ControllerConfig, policy_4p4d
from repro.core.costmodel import H100, MI300X, TPU_V5E
from repro.core.events import EventLoop
from repro.core.fleet import FleetConfig, FleetManager
from repro.core.goodput import RequestRecord
from repro.core.power_manager import PowerManager
from repro.core.simulator import NodeSimulator, SimRequest, Workload

CFG = get_config("llama31_8b")


def dyn(**kw):
    return dataclasses.replace(ControllerConfig(), allow_power=True,
                               allow_gpu=False, **kw)


def make_fleet(n_nodes=3, budget=4000.0, elastic=True, standby=(),
               ctrl="default", shift=True, gpu_move=False, fcfg=None, **kw):
    cs = ClusterSimulator(CFG, policy_4p4d(500), n_nodes,
                          node_budget_w=budget,
                          ctrl_cfg=dyn(ttft_slo=2.0) if ctrl == "default"
                          else ctrl,
                          cluster_cfg=ClusterConfig(
                              allow_shift=shift, allow_gpu_move=gpu_move),
                          **kw)
    fm = FleetManager(cs, fcfg or FleetConfig(elastic=elastic),
                      standby=standby)
    return cs, fm


# ---------------------------------------------------------------------------
# PowerManager membership ops + EventLoop cancellation
# ---------------------------------------------------------------------------

def test_power_off_releases_everything():
    pm = PowerManager(8, 4000.0, initial_caps=[500.0] * 8)
    pm.set_cap(0.0, 0, 400.0)                 # lower in flight
    released = pm.power_off(1.0)
    assert released == pytest.approx(4000.0)
    assert pm.budget == 0.0 and not pm.powered
    assert pm.commanded == [0.0] * 8 and pm.effective == [0.0] * 8
    assert not pm.pending and not pm.budget_op_inflight
    assert pm._worst_case() == 0.0


def test_power_on_uniform_caps_and_floor():
    pm = PowerManager(8, 4000.0, initial_caps=[500.0] * 8)
    pm.power_off(0.0)
    absorbed = pm.power_on(1.0, 4400.0)
    assert absorbed == pytest.approx(4400.0)
    assert pm.effective == [550.0] * 8
    pm.power_off(2.0)
    with pytest.raises(ValueError):
        pm.power_on(3.0, 100.0)               # below the 8 x 400 W floor


def test_event_loop_cancel():
    loop = EventLoop()
    fired = []
    loop.push(1.0, lambda k, p: fired.append((k, p)), "a")
    token = loop.push(2.0, lambda k, p: fired.append((k, p)), "b")
    loop.push(3.0, lambda k, p: fired.append((k, p)), "c")
    loop.cancel(token)
    loop.run(lambda: False)
    assert [k for k, _ in fired] == ["a", "c"]
    assert loop.now == 3.0                    # cancelled event kept the clock


# ---------------------------------------------------------------------------
# graceful leave: drain -> migrate -> power off -> redistribute
# ---------------------------------------------------------------------------

def test_leave_migrates_and_redistributes():
    cs, fm = make_fleet()
    fm.schedule_leave(6.0, 2)
    wl = Workload.uniform(90, qps=7.0, in_tokens=4096, out_tokens=256,
                          seed=4, ttft_slo=2.0)
    s = cs.run(wl)
    assert s.n_finished == 90
    kinds = [k for _, k, _ in fm.churn_trace]
    assert kinds == ["leave", "leave_done"]
    assert len(fm.migration_trace) > 0, "a loaded node must migrate KV out"
    # departed node is dark; its watts re-leveled onto the survivors
    assert cs.nodes[2].pm.budget == 0.0
    assert not cs.active[2]
    assert sum(nd.pm.budget for nd in cs.nodes) == \
        pytest.approx(min(cs.facility_budget_w,
                          2 * cs.nodes[0].pm.budget_ceil_w))
    # migrated records finished on (and are accounted to) surviving nodes
    assert sum(len(nd.live_records()) for nd in cs.nodes) == 90
    assert all(np.isfinite(r.energy_j) and r.energy_j > 0
               for r in cs.records)


def test_leave_mid_prefill_hands_off_and_powers_down():
    """In-flight prefill batches at leave time finish locally, then their
    fresh KV leaves over the interconnect; the node powers off only once
    empty with no outbound transfer in flight."""
    cs, fm = make_fleet()
    # a large pinned prompt burst guarantees in-flight prefill at t=2.0
    pinned = {2: Workload.uniform(20, qps=20.0, in_tokens=8192,
                                  out_tokens=64, seed=1, ttft_slo=3.0)}
    fm.schedule_leave(2.0, 2)
    s = cs.run(Workload.uniform(40, qps=4.0, in_tokens=2048, out_tokens=128,
                                seed=2, ttft_slo=2.0), pinned=pinned)
    assert s.n_finished == 60
    done = [t for t, k, n in fm.churn_trace if k == "leave_done"]
    assert done and done[0] > 2.0
    reasons = {r for _, _, _, r, _ in fm.migration_trace}
    assert "leave" in reasons
    assert cs.nodes[2].is_empty() and cs.nodes[2].defunct


# ---------------------------------------------------------------------------
# failure: state loss, requeue from scratch
# ---------------------------------------------------------------------------

def test_failure_requeues_from_scratch():
    cs, fm = make_fleet()
    fm.schedule_fail(6.0, 1)
    wl = Workload.uniform(90, qps=7.0, in_tokens=4096, out_tokens=256,
                          seed=4, ttft_slo=2.0)
    s = cs.run(wl)
    assert s.n_finished == 90
    assert len(fm.requeue_trace) > 0, "a loaded node must lose work"
    assert len(fm.migration_trace) == 0, "failures cannot migrate KV"
    requeued = {rid for _, rid, _ in fm.requeue_trace}
    by_rid = {r.rid: r for r in cs.records}
    for rid in requeued:
        # re-prefilled after the failure instant — TTFT pays the full price
        assert by_rid[rid].prefill_done > 6.0
        # joules burned before the failure are kept on the record
        assert by_rid[rid].energy_j > 0
    assert cs.nodes[1].defunct and cs.nodes[1].pm.budget == 0.0


def test_failure_redistribution_elastic_vs_static():
    def run(elastic):
        cs, fm = make_fleet(elastic=elastic)
        fm.schedule_fail(5.0, 2)
        s = cs.run(Workload.uniform(120, qps=8.0, in_tokens=4096,
                                    out_tokens=256, seed=4, ttft_slo=2.0))
        return cs, s
    cs_e, s_e = run(True)
    cs_s, s_s = run(False)
    # elastic re-levels the dead node's watts; static strands them
    assert sum(nd.pm.budget for nd in cs_e.nodes) > \
        sum(nd.pm.budget for nd in cs_s.nodes)
    assert s_e.slo_attainment >= s_s.slo_attainment


# ---------------------------------------------------------------------------
# join: DISTRIBUTEUNIFORMPOWER at facility level (source-before-sink)
# ---------------------------------------------------------------------------

def test_standby_join_shrinks_survivors_first():
    cs, fm = make_fleet(n_nodes=3, standby=(2,),
                        facility_budget_w=12000.0)
    # survivors idle at 4000 W each; facility has 4000 W headroom, but the
    # uniform share for 3 nodes is 4000 — no shrink needed, grant immediate
    fm.schedule_join(4.0, 2)
    s = cs.run(Workload.uniform(90, qps=6.0, in_tokens=4096, out_tokens=256,
                                seed=4, ttft_slo=2.0))
    assert s.n_finished == 90
    kinds = [k for _, k, _ in fm.churn_trace]
    assert kinds == ["join", "join_done"]
    assert cs.active[2] and cs.nodes[2].pm.powered
    assert cs.nodes[2].pm.budget == pytest.approx(4000.0)
    assert len(cs.nodes[2].records) > 0, "joiner must take routed traffic"
    cs.assert_facility_invariant()


def test_join_levels_down_overfull_survivors():
    """Survivors sitting above the new uniform share must shrink (and their
    shrinks must be IN FORCE) before the joiner powers on."""
    cs, fm = make_fleet(n_nodes=2, standby=(1,), facility_budget_w=10000.0,
                        node_budgets=[6000.0, 4000.0],
                        policies=[policy_4p4d(750), policy_4p4d(500)])
    fm.schedule_join(3.0, 1)
    s = cs.run(Workload.uniform(60, qps=5.0, in_tokens=4096, out_tokens=256,
                                seed=4, ttft_slo=2.0))
    assert s.n_finished == 60
    joined = [t for t, k, n in fm.churn_trace if k == "join_done"]
    assert joined and joined[0] > 3.0, \
        "join must wait for the survivors' cap lowers to take effect"
    assert cs.nodes[0].pm.budget == pytest.approx(5000.0)
    assert cs.nodes[1].pm.budget == pytest.approx(5000.0)
    cs.assert_facility_invariant()


# ---------------------------------------------------------------------------
# pinned-only traffic role flips (the ROADMAP item migration unlocks)
# ---------------------------------------------------------------------------

def test_last_decode_gpu_flip_migrates_pinned_batch():
    """With a fleet migrator attached, a node may flip its LAST decode GPU
    to prefill: the pinned batch leaves cross-node and later prefills route
    their KV out too — impossible before cross-node migration existed."""
    cs, fm = make_fleet(n_nodes=2, shift=False)
    node = cs.nodes[1]
    # pin a decode-heavy stream so node 1 carries pinned-only decode work,
    # plus a late wave that arrives AFTER the node has gone full-prefill
    wl1 = Workload.uniform(24, qps=6.0, in_tokens=500, out_tokens=400,
                           seed=6, tpot_slo=0.040)
    late = Workload([(4.5 + 0.2 * i, 500, 200, 1.0, 0.040)
                     for i in range(8)])
    pinned = {1: Workload(wl1.entries + late.entries)}
    cs._seed_arrivals(None, pinned)
    for nd in cs.nodes:
        nd.start()
    cs.loop.push(0.0, cs._handle, "cluster_ctrl")
    # let decode batches form, then flip decode->prefill down to zero
    while cs.loop.heap and cs.loop.now < 4.0:
        cs.loop.step()
    flips = 0
    while node.can_flip("d2p", allow_empty=True):
        assert node.request_role_flip("d2p")
        flips += 1
    assert flips == 4, "all four decode GPUs must be flippable"
    cs.loop.run(lambda: cs.n_unfinished() == 0)
    assert all(r.finish is not None for r in cs.records)
    reasons = {rec[3] for rec in fm.migration_trace}
    assert "role_flip" in reasons, "the live batch must migrate out"
    assert "no_decode_role" in reasons, \
        "post-flip prefill completions must route their KV cross-node"
    assert all(g.role == "prefill" for g in node.gpus)


def test_can_flip_last_decode_requires_migrator():
    sim = NodeSimulator(CFG, policy_4p4d(500), node_budget_w=4000.0,
                        ctrl_cfg=dyn())
    for _ in range(3):
        assert sim.request_role_flip("d2p")
        while sim.loop.heap:
            sim.loop.step()
    # at one decode GPU: refused without a migrator, allowed with one
    assert not sim.can_flip("d2p", allow_empty=True)
    sim.migrator = lambda *a: None
    assert sim.can_flip("d2p", allow_empty=True)
    assert not sim.can_flip("d2p")            # configured floor still holds


# ---------------------------------------------------------------------------
# router policies: joules vs capacity
# ---------------------------------------------------------------------------

def test_joules_router_ties_break_capacity_relative():
    """Identical idle hardware prices identically — the joules policy must
    then fall back to the capacity-relative load and avoid the node with
    queued work, exactly like the capacity policy would."""
    cs = ClusterSimulator(CFG, policy_4p4d(500), 2, node_budget_w=4000.0,
                          router_policy="joules")
    j0 = cs.nodes[0].marginal_joules_per_token(4096, 256)
    j1 = cs.nodes[1].marginal_joules_per_token(4096, 256)
    assert j0 == j1
    for i in range(6):
        cs.nodes[0].submit(SimRequest(RequestRecord(100 + i, 0.0, 8192, 16)))
    picked = {cs.router.pick(0.0, cs.nodes).node_id for _ in range(4)}
    assert picked == {1}


def test_joules_router_prefers_cheaper_hardware():
    """A TPU-v5e pool at 200 W caps prices a token below an MI300X pool at
    500 W; the joules policy routes there while capacity routes to the
    faster MI300X pool."""
    cfg = get_config("qwen1_5_4b")          # fits the v5e HBM envelope
    def run(policy):
        cs = ClusterSimulator(cfg, policy_4p4d(500), 2,
                              node_budget_w=4000.0,
                              gpu_specs=[MI300X, TPU_V5E],
                              router_policy=policy, seed=0)
        assert cs.nodes[1].marginal_joules_per_token(2000, 128) < \
            cs.nodes[0].marginal_joules_per_token(2000, 128)
        s = cs.run(Workload.uniform(40, qps=3.0, in_tokens=2000,
                                    out_tokens=128, seed=1))
        assert s.n_finished == 40
        return [len(nd.records) for nd in cs.nodes], s
    counts_cap, s_cap = run("capacity")
    counts_j, s_j = run("joules")
    assert counts_cap[0] > counts_cap[1]
    assert counts_j[1] > counts_j[0]
    # the energy price signal must be realized, not just predicted
    assert s_j.energy_per_good_token_j < s_cap.energy_per_good_token_j


# ---------------------------------------------------------------------------
# TPU-v5e wiring: mixed three-vendor cluster end-to-end
# ---------------------------------------------------------------------------

def test_mixed_mi300x_h100_tpu_cluster_routes_and_finishes():
    """One shared StaticPolicy + default budgets must land correctly on all
    three specs: caps clamp to each node's envelope and budgets derive from
    the spec ceiling (a TPU-v5e node cannot hold MI300X watts)."""
    cfg = get_config("qwen1_5_4b")
    cs = ClusterSimulator(cfg, policy_4p4d(500), 3, node_budget_w=4000.0,
                          gpu_specs=[MI300X, H100, TPU_V5E], seed=0)
    assert [nd.pm.budget for nd in cs.nodes] == [4000.0, 4000.0, 1600.0]
    assert cs.facility_budget_w == pytest.approx(9600.0)
    assert cs.nodes[2].pm.effective == [200.0] * 8   # spec-clamped caps
    assert cs.nodes[2].pm.min_cap == 110.0
    # pin streams so every vendor actually serves; route the rest
    pinned = {i: Workload.uniform(10, qps=2.0, in_tokens=1000, out_tokens=64,
                                  seed=10 + i) for i in range(3)}
    s = cs.run(Workload.uniform(30, qps=4.0, in_tokens=2000, out_tokens=64,
                                seed=1), pinned=pinned)
    assert s.n_finished == 60
    assert all(len(nd.records) >= 10 for nd in cs.nodes)
    assert all(np.isfinite(r.energy_j) and r.energy_j > 0
               for r in cs.records)


def test_arrivals_and_work_survive_a_fully_dark_fleet_window():
    """Regression: with every node down (single-node fleet in a maintenance
    window), routed arrivals and in-flight migrations must defer and retry
    — not crash the router on an empty membership — and the rejoin must not
    double-grant the watts a deferred re-offer still claims."""
    cs, fm = make_fleet(n_nodes=1, shift=False)
    fm.schedule_leave(1.0, 0)
    fm.schedule_join(4.0, 0)
    wl = Workload.uniform(12, qps=4.0, in_tokens=2048, out_tokens=64,
                          seed=3, ttft_slo=2.0)
    s = cs.run(wl)
    assert s.n_finished == 12
    kinds = [k for _, k, _ in fm.churn_trace]
    assert kinds == ["leave", "leave_done", "join", "join_done"]
    assert cs.nodes[0].pm.budget == pytest.approx(4000.0)
    cs.assert_facility_invariant()


# ---------------------------------------------------------------------------
# elastic vs static under the same churn (fig11 in miniature)
# ---------------------------------------------------------------------------

def test_elastic_beats_static_under_churn():
    def run(elastic):
        cs, fm = make_fleet(elastic=elastic)
        fm.schedule_leave(6.0, 2)
        fm.schedule_join(18.0, 2)
        wl = Workload.uniform(160, qps=9.0, in_tokens=4096, out_tokens=256,
                              seed=4, ttft_slo=2.0)
        s = cs.run(wl)
        assert s.n_finished == 160
        return s
    s_e = run(True)
    s_s = run(False)
    assert s_e.slo_attainment >= s_s.slo_attainment
    assert all(np.isfinite(x) for x in
               (s_e.energy_per_good_token_j, s_s.energy_per_good_token_j))
