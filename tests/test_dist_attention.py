"""Numerical correctness of the cross-chip flash-decoding path
(dist_decode_attention) on a multi-device host mesh. Runs in a subprocess so
the main test process keeps the default single-device backend."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import layers as L
from repro.models.sharding import standard_rules, use_rules
from repro.kernels.decode_attention.ref import decode_attention_ref

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = standard_rules(False)
rules["kv_seq"] = "model"

B, S, Hq, K, hd, pos = 2, 64, 8, 2, 16, 41
key = jax.random.key(0)
ks = jax.random.split(key, 5)
q = jax.random.normal(ks[0], (B, 1, Hq, hd), jnp.float32)
kc = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
vc = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
kn = jax.random.normal(ks[3], (B, 1, K, hd), jnp.float32)
vn = jax.random.normal(ks[4], (B, 1, K, hd), jnp.float32)

def run(q, kc, vc, kn, vn):
    with use_rules(rules, mesh):
        return L.dist_decode_attention(q, kc, vc, kn, vn, pos)

cs = NamedSharding(mesh, P("data", "model", None, None))
with mesh:
    out, kc2, vc2 = jax.jit(run, in_shardings=(
        NamedSharding(mesh, P("data",)), cs, cs,
        NamedSharding(mesh, P("data",)), NamedSharding(mesh, P("data",))
    ))(q, kc, vc, kn, vn)

# reference: write the new token at pos, then plain decode attention
kc_ref = kc.at[:, pos].set(kn[:, 0])
vc_ref = vc.at[:, pos].set(vn[:, 0])
ref = decode_attention_ref(q[:, 0], kc_ref, vc_ref, pos)
err = float(jnp.max(jnp.abs(out[:, 0] - ref)))
assert err < 2e-5, err
np.testing.assert_allclose(np.asarray(kc2), np.asarray(kc_ref), atol=1e-6)
np.testing.assert_allclose(np.asarray(vc2), np.asarray(vc_ref), atol=1e-6)
print("DIST_ATTENTION_OK", err)
"""


def test_dist_decode_attention_multidevice():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "DIST_ATTENTION_OK" in r.stdout, r.stdout + r.stderr
