"""End-to-end system behaviour: the full paper pipeline in miniature —
train a model, serve it disaggregated under RAPID control, and check that
power-aware scheduling beats static under the paper's workload shape."""
import dataclasses

import numpy as np

from repro.configs import get_config
from repro.core.controller import ControllerConfig, policy_4p4d
from repro.core.simulator import NodeSimulator, Workload
from repro.serving.engine import DisaggEngine
from repro.training.train_loop import train


def test_train_then_serve_end_to_end(rng):
    cfg = get_config("qwen1_5_4b").reduced()
    params, hist = train(cfg, steps=8, batch_size=2, seq_len=32, log_every=0,
                         remat=False)
    eng = DisaggEngine(cfg, n_prefill=1, n_decode=1, max_len=48,
                       decode_slots=2)
    eng.params = params                     # serve the trained weights
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                   6, 0.0)
    s = eng.run()
    assert s.n_finished == 3


def test_rapid_improves_peak_load_slo():
    """Headline claim: up to ~2x SLO attainment at peak vs static."""
    cfg = get_config("llama3.1-8b")
    ctrl = dataclasses.replace(ControllerConfig(), allow_power=True,
                               allow_gpu=True)
    wl = Workload.sonnet_phases(6.5, seed=5, n1=300, n2=300)
    s_static = NodeSimulator(cfg, policy_4p4d(600)).run(wl)
    wl = Workload.sonnet_phases(6.5, seed=5, n1=300, n2=300)
    s_dyn = NodeSimulator(cfg, policy_4p4d(600), ctrl_cfg=ctrl).run(wl)
    assert s_dyn.slo_attainment >= 1.5 * s_static.slo_attainment
